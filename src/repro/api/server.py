"""SenecaServer + Session: the public face of the cache/sampler service.

The seed exposed the paper's Figure-7 loop as :class:`SenecaService` with
raw ``job_id`` ints threaded through every call and pipelines poking
``svc.cache.parts[...]`` for admission.  This module keeps that engine
(same name, now policy-driven) and wraps it in a session facade::

    server = SenecaServer.for_dataset(ds, cache_frac=0.35)
    with server.open_session(batch_size=32) as sess:
        ids, forms = sess.next_batch_ids()
        ...
    print(server.stats())

Sessions own job registration/unregistration — opening one bumps the ODS
job count (and with it the refcount-eviction threshold), closing it drops
both — so the paper's headline many-jobs-one-cache scenario is just N
``open_session`` calls against one server.

Construction knobs (``SenecaConfig`` fields or ``SenecaServer`` kwargs):
``backend`` selects the ODS metadata engine ("numpy" | "jax" — the latter
runs the fused ``ods_jax.substitute_jit`` kernel), and ``sampler`` /
``admission`` / ``eviction`` select policies by registered name
(see :mod:`repro.api.policies`).

``repartition`` selects how the cache split tracks the workload:
``"static"`` (construction-time MDP, the default), ``"on-change"``
(re-solve when sessions open/close) or ``"adaptive"`` (additionally
re-solve on telemetry-calibrated drift and resize the TieredCache live
— see :class:`RepartitionController` and docs/API.md).
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple

import numpy as np

from repro.api.backends import (NO_REFCOUNT_EVICT, resolve_augment_backend,
                                resolve_backend)
from repro.api.policies import resolve_policy
from repro.api.telemetry import TelemetryAggregator
from repro.cache.coalesce import ProductionTable
from repro.cache.store import FORMS, TieredCache
from repro.core import mdp
from repro.core.ods import (AUGMENTED, DECODED, ENCODED, IN_STORAGE,
                            EpochSampler)
from repro.core.perf_model import (AZURE_NC96, DEFAULT_DISK_BW,
                                   DEFAULT_HBM_BW, DatasetProfile,
                                   HardwareProfile, JobProfile, calibrate)

__all__ = ["SenecaConfig", "SenecaService", "SenecaServer", "Session",
           "SessionClosed", "RepartitionController", "SLO", "FORM_CODE",
           "CODE_FORM"]


@dataclass(frozen=True)
class SLO:
    """Tail-latency service-level objective for open-loop serving.

    The open-loop admission controller
    (:class:`~repro.workload.openloop.OpenLoopGenerator`) estimates each
    arriving request's queue wait as ``backlog x service-time EWMA /
    workers`` and compares it against ``p99_target_s``:

    * estimated wait > ``degrade_frac`` x target — skip augmentation
      (serve the decoded form);
    * estimated wait > ``encode_frac`` x target — serve the encoded
      form (skip decode *and* augment);
    * estimated wait > ``shed_frac`` x target, or the queue is at
      ``max_queue`` — shed the request outright.

    Degrading caps the *work* a request may buy, never the served
    quality of an already-cached form: a request degraded to encoded is
    still answered from the augmented cache partition when it hits.
    Every decision is counted (``shed`` / ``degraded``) and surfaced in
    ``stats()["telemetry"]["requests"]``.
    """

    p99_target_s: float
    max_queue: int = 256          # hard backlog bound (shed beyond it)
    degrade_frac: float = 0.5     # skip augment past this fraction
    encode_frac: float = 0.75     # serve encoded past this fraction
    shed_frac: float = 1.0        # shed past this fraction

    def __post_init__(self) -> None:
        if not self.p99_target_s > 0:
            raise ValueError(f"p99_target_s must be > 0, got "
                             f"{self.p99_target_s}")
        if self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got "
                             f"{self.max_queue}")
        if not (0 < self.degrade_frac <= self.encode_frac
                <= self.shed_frac):
            raise ValueError(
                f"expected 0 < degrade_frac <= encode_frac <= shed_frac, "
                f"got {self.degrade_frac}/{self.encode_frac}/"
                f"{self.shed_frac}")


REPARTITION_MODES = ("static", "on-change", "adaptive")

FORM_CODE = {"encoded": ENCODED, "decoded": DECODED, "augmented": AUGMENTED}
CODE_FORM = {v: k for k, v in FORM_CODE.items()}


class SessionClosed(RuntimeError):
    """Raised when a closed Session is asked to sample."""


@dataclass
class SenecaConfig:
    cache_bytes: int
    hardware: HardwareProfile
    dataset: DatasetProfile
    job: JobProfile = field(default_factory=JobProfile)
    partition_step: float = 0.01
    seed: int = 0
    use_ods: bool = True          # False -> MDP-only (paper's "MDP" bar)
    # manual override (x_e, x_d, x_a); None -> run MDP
    split: Optional[Tuple[float, float, float]] = None
    # facade knobs: ODS metadata engine + policies by registered name
    backend: str = "numpy"
    # batched augmentation engine for the stage-parallel pipeline executor
    # ("numpy" loop fallback | "pallas"/"jax" fused kernel); the
    # per-sample executor keeps its inline augment_np path either way
    augment_backend: str = "numpy"
    sampler: Optional[str] = None      # None -> "ods" / "naive" per use_ods
    admission: Optional[str] = None    # None -> "unseen-only" / "capacity"
    eviction: Optional[str] = None     # None -> "refcount"
    # SSD spill tier: a directory + byte budget turn every partition
    # into a DRAM→disk chain (evictions demote, disk hits promote, the
    # MDP partitions form×tier).  Default off = single-tier behavior,
    # byte-identical to the pre-spill engine.
    spill_dir: Optional[str] = None
    spill_bytes: int = 0
    # manual disk split (y_e, y_d, y_a); None -> form×tier MDP (or the
    # DRAM split when that is manual too)
    spill_split: Optional[Tuple[float, float, float]] = None
    # device-resident cache tier: >0 puts an HBM level at the head of
    # every partition chain (array payloads device_put on insert, hot
    # DRAM hits promoted up, served zero-copy).  Default off =
    # two-level behavior, byte-identical to the pre-HBM engine.
    device_cache_bytes: int = 0
    # manual HBM split (z_e, z_d, z_a); None -> three-level MDP (or the
    # DRAM split when that is manual too)
    hbm_split: Optional[Tuple[float, float, float]] = None
    # live repartitioning (RepartitionController):
    #   "static"    — solve the MDP once at construction (seed behavior)
    #   "on-change" — re-solve when sessions open/close
    #   "adaptive"  — "on-change" + telemetry-calibrated drift ticks
    repartition: str = "static"
    repartition_drift: float = 0.15    # re-solve when calibrated prediction
    #                                    of the live split drifts this much
    repartition_gain: float = 0.05     # apply only if predicted gain clears
    repartition_cooldown: float = 1.0  # min seconds between adaptive ticks
    repartition_period: float = 0.0    # >0: background tick thread period
    telemetry_min_samples: int = 32    # per-signal floor for calibrate()
    # sharded data plane (src/repro/service/): >1 splits the cache
    # across N shards behind a consistent-hash router.  "sim" keeps the
    # shards in-process (deterministic, VirtualClock-safe); "process"
    # gives each shard its own OS process (payloads move zero-copy via
    # codec files + np.memmap).  shards=1 + "sim" keeps the classic
    # single TieredCache — byte-identical to the pre-shard engine.
    shards: int = 1
    shard_transport: str = "sim"
    # tail-latency SLO for open-loop serving (docs/API.md "Open-loop
    # serving & SLOs"): None disables admission control — requests
    # queue unboundedly like the closed-loop path.  The
    # OpenLoopGenerator defaults to this when not given its own.
    slo: Optional[SLO] = None
    # concurrency layer (docs/API.md "Concurrency: coalescing & lock
    # striping").  lock_stripes>1 hash-stripes the TieredCache key
    # space over that many independent locks (single-process cache
    # only; shards already partition the key space).  coalesce=True
    # single-flights concurrent productions of the same (sample, form)
    # across every session of this service; coalesce_timeout_s bounds
    # a joiner's wall-clock wait before it falls back to producing.
    lock_stripes: int = 1
    coalesce: bool = True
    coalesce_timeout_s: float = 5.0


class RepartitionController:
    """Closes the loop between telemetry and the MDP split (§5.1/§5.3).

    The static pipeline is: solve the MDP once at construction and never
    look back.  This controller re-solves with a telemetry-**calibrated**
    hardware profile and resizes the live :class:`TieredCache` when it is
    predicted to pay off, with two layers of hysteresis against churn:

    * **re-solve gate** — adaptive ticks only re-run the (cached-grid)
      simplex pass when the calibrated model's prediction for the *live*
      split has drifted more than ``repartition_drift`` from the
      prediction recorded when that split was chosen (plus a
      ``repartition_cooldown`` floor between ticks).  Session open/close
      always re-solves ("on-change" + "adaptive" modes): that is the
      paper's concurrent-jobs trigger and costs <1s.
    * **apply gate** — a re-solved split is applied only when it differs
      from the live one and its predicted throughput clears
      ``repartition_gain`` over the live split's (both under the same
      calibrated profile).

    Steady telemetry therefore converges: the first qualifying re-solve
    re-baselines the drift reference, and subsequent ticks no-op.
    """

    MAX_EVENTS = 64

    def __init__(self, service: "SenecaService"):
        self.service = service
        cfg = service.cfg
        self.mode = cfg.repartition
        self._lock = threading.Lock()
        self._solver: Optional[mdp.IncrementalSolver] = None
        self._baseline: Optional[float] = None   # model view of live split
        self._last_tick = float("-inf")
        self.resolves = 0
        self.applied = 0
        self.skipped = 0
        self.events: list = []
        self._last_applied: Optional[dict] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        if self.mode == "adaptive" and cfg.repartition_period > 0:
            self._thread = threading.Thread(
                target=self._run, name="seneca-repartition", daemon=True)
            self._thread.start()

    # -- plumbing ------------------------------------------------------
    @property
    def active(self) -> bool:
        return self.mode != "static" and not self._stop.is_set()

    def _run(self) -> None:
        period = self.service.cfg.repartition_period
        while not self._stop.wait(period):
            try:
                self.tick()
            except Exception:        # pragma: no cover - must never kill
                pass                 # the host process from a daemon tick

    def stop(self) -> None:
        """Deactivate: no further re-solves fire (session churn during
        server teardown must not resize a cache about to be dropped)."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)

    def _get_solver(self) -> mdp.IncrementalSolver:
        if self._solver is None:
            cfg = self.service.cfg
            self._solver = mdp.IncrementalSolver(cfg.dataset, cfg.job,
                                                 cfg.partition_step)
        return self._solver

    def _calibrated(self):
        return calibrate(self.service.hardware,
                         self.service.telemetry.snapshot(),
                         self.service.cfg.telemetry_min_samples)

    def _live_split(self):
        p = self.service.partition
        return (p.x_e, p.x_d, p.x_a)

    def _live_disk_split(self):
        p = self.service.disk_partition
        return (p.x_e, p.x_d, p.x_a) if p is not None else None

    def _live_hbm_split(self):
        p = self.service.hbm_partition
        return (p.x_e, p.x_d, p.x_a) if p is not None else None

    def _tiered(self) -> bool:
        return (self.service.disk_partition is not None
                or self.service.hbm_partition is not None)

    def _predict_live(self, solver, hw) -> float:
        if self._tiered():
            return solver.predict_tiered(hw, self._live_split(),
                                         self._live_disk_split()
                                         or (1.0, 0.0, 0.0),
                                         self._live_hbm_split())
        return solver.predict(hw, self._live_split())

    # -- triggers ------------------------------------------------------
    def on_sessions_changed(self) -> bool:
        """Session open/close: unconditional re-solve (apply still gated)."""
        if not self.active:
            return False
        with self._lock:
            return self._resolve_locked(self._calibrated(), "sessions")

    def _now(self) -> float:
        """Cooldown time source: the server's pluggable clock when one
        is configured (``SenecaService.set_clock``), else wall time.
        Gating the adaptive cadence on ``time.monotonic`` under a
        VirtualClock made the repartition rhythm depend on host CPU
        speed instead of trace time — a determinism leak."""
        clock = self.service.clock
        return time.monotonic() if clock is None else clock.now()

    def tick(self) -> bool:
        """Adaptive drift check; returns True when a resize was applied."""
        if self.mode != "adaptive" or self._stop.is_set():
            return False
        with self._lock:
            now = self._now()
            if now - self._last_tick < self.service.cfg.repartition_cooldown:
                return False
            self._last_tick = now
            hw = self._calibrated()
            solver = self._get_solver()
            pred_live = self._predict_live(solver, hw)
            if self._baseline is None or not np.isfinite(self._baseline):
                # manual-split servers carry throughput=NaN; anchor the
                # drift reference on the uncalibrated model's view
                base = self.service.partition.throughput
                self._baseline = base if np.isfinite(base) else \
                    self._predict_live(solver, self.service.hardware)
            drift = abs(pred_live - self._baseline) / max(self._baseline,
                                                          1e-12)
            if drift <= self.service.cfg.repartition_drift:
                return False
            return self._resolve_locked(hw, "drift", pred_live=pred_live)

    # -- the re-solve + hysteresis-gated apply -------------------------
    def _resolve_locked(self, hw, trigger: str,
                        pred_live: Optional[float] = None) -> bool:
        solver = self._get_solver()
        live = self._live_split()
        if pred_live is None:
            pred_live = self._predict_live(solver, hw)
        best_disk = best_hbm = None
        if self._tiered():
            # form×tier re-solve: all configured levels move together,
            # and the gain gate compares combined multi-level predictions
            tiered = solver.solve_tiered(hw)
            best, best_disk = tiered.dram, tiered.disk
            best_hbm = tiered.hbm
            best_thr, to_label = tiered.throughput, tiered.label
            changed = live != (best.x_e, best.x_d, best.x_a)
            if self.service.disk_partition is not None:
                changed = changed or (self._live_disk_split()
                                      != (best_disk.x_e, best_disk.x_d,
                                          best_disk.x_a))
            if best_hbm is not None:
                changed = changed or (self._live_hbm_split()
                                      != (best_hbm.x_e, best_hbm.x_d,
                                          best_hbm.x_a))
            parts = [self.service.partition.label]
            if self.service.hbm_partition is not None:
                parts.insert(0, self.service.hbm_partition.label)
            if self.service.disk_partition is not None:
                parts.append(self.service.disk_partition.label)
            from_label = "|".join(parts)
        else:
            best = solver.solve(hw)
            best_thr, to_label = best.throughput, best.label
            changed = (best.x_e, best.x_d, best.x_a) != live
            from_label = self.service.partition.label
        self.resolves += 1
        gain = (best_thr - pred_live) / max(pred_live, 1e-12)
        apply = changed and gain > self.service.cfg.repartition_gain
        event = {"trigger": trigger, "profile": hw.name,
                 "from": from_label, "to": to_label,
                 "predicted_gain": round(float(gain), 4),
                 "applied": bool(apply)}
        if apply:
            event["demoted"] = self.service.apply_partition(best, best_disk,
                                                            best_hbm)
            self.applied += 1
            self._baseline = best_thr
            self._last_applied = event
        else:
            self.skipped += 1
            self._baseline = pred_live
        self.events.append(event)
        del self.events[:-self.MAX_EVENTS]
        return apply

    def summary(self) -> Dict[str, object]:
        with self._lock:
            return {"mode": self.mode, "resolves": self.resolves,
                    "applied": self.applied, "skipped": self.skipped,
                    "partition": self.service.partition.label,
                    "last": dict(self.events[-1]) if self.events else None,
                    "last_applied": dict(self._last_applied)
                    if self._last_applied else None}


class SenecaService:
    """One shared dataset's cache + sampler engine (policy-driven).

    Prefer :class:`SenecaServer` / :class:`Session`; this class remains the
    synchronous engine underneath and the back-compat surface for the old
    ``register_job``/``job_id`` call style.
    """

    def __init__(self, cfg: SenecaConfig, *, backend=None, sampler=None,
                 admission=None, eviction=None, augment_backend=None):
        self.cfg = cfg
        if cfg.repartition not in REPARTITION_MODES:
            raise ValueError(f"unknown repartition mode "
                             f"{cfg.repartition!r}; expected one of "
                             f"{REPARTITION_MODES}")
        if cfg.shards < 1:
            raise ValueError(f"shards must be >= 1, got {cfg.shards}")
        # base profile with the *configured* cache size: the static solve,
        # and later every calibrated re-solve, all run against this
        self.hardware = cfg.hardware
        if self.hardware.s_cache != cfg.cache_bytes:
            self.hardware = replace(self.hardware,
                                    s_cache=float(cfg.cache_bytes))
        self.has_spill = bool(cfg.spill_dir) and cfg.spill_bytes > 0
        if self.has_spill:
            hw_over = {"s_disk": float(cfg.spill_bytes)}
            if self.hardware.b_disk <= 0:
                # local-SSD read-bandwidth prior until telemetry
                # calibrates the real rate (CALIBRATABLE includes b_disk)
                hw_over["b_disk"] = DEFAULT_DISK_BW
            self.hardware = replace(self.hardware, **hw_over)
        self.has_hbm = cfg.device_cache_bytes > 0
        if self.has_hbm:
            hw_over = {"s_hbm": float(cfg.device_cache_bytes)}
            if self.hardware.b_hbm <= 0:
                # host→device link-rate prior until the "h2d" telemetry
                # channel calibrates it (CALIBRATABLE includes b_hbm)
                hw_over["b_hbm"] = DEFAULT_HBM_BW
            self.hardware = replace(self.hardware, **hw_over)
        self.disk_partition: Optional[mdp.Partition] = None
        self.hbm_partition: Optional[mdp.Partition] = None
        if cfg.split is not None:
            self.partition = mdp.Partition(*cfg.split, throughput=float("nan"))
            if self.has_spill:
                self.disk_partition = mdp.Partition(
                    *(cfg.spill_split or cfg.split),
                    throughput=float("nan"))
            if self.has_hbm:
                self.hbm_partition = mdp.Partition(
                    *(cfg.hbm_split or cfg.split),
                    throughput=float("nan"))
        elif self.has_spill or self.has_hbm:
            tiered = mdp.optimize_tiered(self.hardware, cfg.dataset,
                                         cfg.job, cfg.partition_step)
            self.partition = tiered.dram
            if self.has_spill:
                self.disk_partition = mdp.Partition(
                    *(cfg.spill_split or (tiered.disk.x_e, tiered.disk.x_d,
                                          tiered.disk.x_a)),
                    throughput=tiered.throughput)
            if self.has_hbm:
                solved_hbm = tiered.hbm or tiered.dram
                self.hbm_partition = mdp.Partition(
                    *(cfg.hbm_split or (solved_hbm.x_e, solved_hbm.x_d,
                                        solved_hbm.x_a)),
                    throughput=tiered.throughput)
        else:
            self.partition = mdp.optimize(self.hardware, cfg.dataset,
                                          cfg.job, cfg.partition_step)
        self.sampler = resolve_policy(
            "sampler", sampler or cfg.sampler
            or ("ods" if cfg.use_ods else "naive"))
        self.admission = resolve_policy(
            "admission", admission or cfg.admission
            or ("unseen-only" if cfg.use_ods else "capacity"))
        self.eviction = resolve_policy(
            "eviction", eviction or cfg.eviction or "refcount")
        split_t = (self.partition.x_e, self.partition.x_d,
                   self.partition.x_a)
        spill_t = ((self.disk_partition.x_e, self.disk_partition.x_d,
                    self.disk_partition.x_a)
                   if self.disk_partition else None)
        hbm_t = ((self.hbm_partition.x_e, self.hbm_partition.x_d,
                  self.hbm_partition.x_a)
                 if self.hbm_partition else None)
        if cfg.shards > 1 or cfg.shard_transport != "sim":
            # lazy import: repro.service must stay importable without
            # repro.api (its shard module imports telemetry lazily for
            # the same reason) — a top-level import here would cycle
            from repro.service.client import ShardedCache
            self.cache = ShardedCache(
                cfg.cache_bytes, split_t,
                evict_policies=self.eviction.partition_policies(),
                spill_bytes=cfg.spill_bytes if self.has_spill else 0,
                spill_dir=cfg.spill_dir if self.has_spill else None,
                spill_split=spill_t,
                hbm_bytes=cfg.device_cache_bytes if self.has_hbm else 0,
                hbm_split=hbm_t,
                shards=cfg.shards, transport=cfg.shard_transport,
                seed=cfg.seed, admission=self.admission,
                hardware=self.hardware, dataset_profile=cfg.dataset,
                job=cfg.job, partition_step=cfg.partition_step,
                # a pinned split stays pinned on every shard; an MDP
                # split re-solves per shard over the 1/N view
                solve_per_shard=cfg.split is None)
        else:
            self.cache = TieredCache(
                cfg.cache_bytes, split_t,
                evict_policies=self.eviction.partition_policies(),
                spill_bytes=cfg.spill_bytes if self.has_spill else 0,
                spill_dir=cfg.spill_dir if self.has_spill else None,
                spill_split=spill_t,
                hbm_bytes=cfg.device_cache_bytes if self.has_hbm else 0,
                hbm_split=hbm_t,
                n_stripes=cfg.lock_stripes)
        try:
            self.backend = resolve_backend(backend or cfg.backend,
                                           cfg.dataset.n_total,
                                           seed=cfg.seed)
            self.augment = resolve_augment_backend(
                augment_backend or cfg.augment_backend)
            self.rng = np.random.default_rng(cfg.seed + 1)
            self._residency_version = -1     # force the first push
            self._samplers: Dict[int, EpochSampler] = {}
            self._lock = threading.Lock()
            self._refill_pending: list = []
            self._batch_counter = itertools.count()
            self.telemetry = TelemetryAggregator()
            # shared across every session/pipeline of this service —
            # that sharing IS the cross-job coalescing (the first
            # misser of a (sample, form) produces, the others join)
            self.production = ProductionTable(
                enabled=cfg.coalesce, timeout_s=cfg.coalesce_timeout_s)
            # pluggable time source (duck-typed Clock: .now()) for every
            # component that paces itself against trace time — the
            # adaptive repartition cooldown reads it, the WorkloadRunner
            # and OpenLoopGenerator install theirs (None = wall time)
            self.clock = None
            self.controller = RepartitionController(self)
        except BaseException:
            # close-after-failed-start: a half-built service must not
            # leak spill files or shard processes
            self.cache.close()
            raise

    # legacy alias: the engine's ODS metadata (numpy state or jax adapter)
    @property
    def ods(self):
        return getattr(self.backend, "state", self.backend)

    # ------------------------------------------------------------------
    def register_job(self, job_id: int, batch_size: int,
                     sampler=None) -> None:
        """Register a job.  ``sampler`` selects the request stream: None
        keeps the historical uniform :class:`EpochSampler`; a name from
        :data:`repro.workload.samplers.REQUEST_SAMPLERS` ("zipfian",
        "phase-shift") or a ``(n, bs, seed) -> sampler`` callable swaps
        in skewed/shifting traffic for this job only."""
        seed = self.cfg.seed + 97 * (job_id + 1)
        if sampler is None:
            smp = EpochSampler(self.cfg.dataset.n_total, batch_size, seed)
        else:
            # lazy import: repro.api must stay importable without
            # repro.workload (which imports the pipeline layer)
            from repro.workload.samplers import make_request_sampler
            smp = make_request_sampler(sampler, self.cfg.dataset.n_total,
                                       batch_size, seed)
        with self._lock:
            self.backend.register_job(job_id)
            self._samplers[job_id] = smp
        # outside the metadata lock: the controller's apply path takes it
        self.controller.on_sessions_changed()

    def unregister_job(self, job_id: int) -> None:
        with self._lock:
            self.backend.unregister_job(job_id)
            self._samplers.pop(job_id, None)
        self.controller.on_sessions_changed()

    # ------------------------------------------------------------------
    def next_batch_ids(self, job_id: int) -> Tuple[np.ndarray, np.ndarray]:
        """Sample a batch for ``job_id``.

        Returns (ids, forms): forms is the uint8 status of each id, i.e.
        which tier will serve it (0 = storage fetch).
        """
        # cost-aware eviction feedback: periodically push the latest
        # telemetry-measured per-form recompute costs into the cache's
        # "cost" tiers (no-op for policies without a refresh hook)
        refresh = getattr(self.eviction, "refresh", None)
        if refresh is not None and next(self._batch_counter) % 32 == 0:
            refresh(self.cache, self.telemetry.snapshot())
        with self._lock:
            if self.has_spill or self.has_hbm:
                # patch metadata for any keys the chains shed since the
                # last batch (spill overflow / promotion backfill / HBM
                # demotion), then give the sampler the current tier
                # levels so it can prefer device hits over DRAM hits
                # over disk hits over storage misses.  The O(N)
                # residency rebuild is version-gated: skipped whenever
                # no insert/evict/resize/promotion touched the cache
                # since the last push
                self._reconcile_evictions_locked()
                version = self.cache.version
                if version != self._residency_version:
                    self.backend.set_residency(
                        self.cache.residency_array(
                            self.cfg.dataset.n_total))
                    self._residency_version = version
            # deprioritize in-flight productions: when the coalescing
            # table has live flights, tell the sampler so substitution
            # and uncached fills prefer ids nobody is producing yet.
            # inflight_mask() is None whenever the table is idle — the
            # common case, and always with coalescing off — which keeps
            # the sampler on its byte-identical mask-free path
            set_inflight = getattr(self.backend, "set_inflight", None)
            if set_inflight is not None:
                set_inflight(self.production.inflight_mask(
                    self.cfg.dataset.n_total)
                    if self.production.enabled else None)
            requested = self._samplers[job_id].next_request()
            thr = self.eviction.threshold(self.backend)
            batch, evicted = self.sampler.sample(
                self.backend, job_id, requested,
                NO_REFCOUNT_EVICT if thr is None else thr)
            if len(evicted):
                for k in evicted:
                    self.cache.evict(int(k), "augmented")
                self._refill_pending.extend(int(k) for k in evicted)
            forms = self.backend.status_of(batch)
            return batch, forms

    # ------------------------------------------------------------------
    def admit(self, sample_id: int, form: str, value, nbytes: int) -> bool:
        """Policy-gated insert; updates ODS status on success.

        The metadata vote (``AdmissionPolicy.wants``) runs under the
        service lock, the capacity vote + insert run atomically under the
        cache lock (no check-then-act window between them).
        """
        # fast path for tiers the current split zeroes out (pipeline
        # workers admit every produced form on the hot path).  The
        # unlocked capacity read is safe: under "static" repartitioning
        # capacities never change, and a concurrent resize() at worst
        # costs this one admission — the next call re-reads.  With a
        # spill chain the disk level counts: a zero-DRAM form can still
        # cache on disk.
        if self.cache.total_capacity(form) == 0:
            return False
        with self._lock:
            if not self.admission.wants(self.backend, sample_id, form):
                return False
        ok = self.cache.insert_gated(sample_id, form, value, nbytes,
                                     self.admission)
        if ok:
            with self._lock:
                # under live repartitioning a resize may have evicted the
                # entry between the insert and this deferred mark; marking
                # anyway would leave phantom CACHED metadata.  Re-validate
                # residency inside the metadata lock (same metadata->cache
                # nesting as apply_partition's scan, so the two serialize).
                if self.controller.active:
                    ok = self.cache.contains(form, sample_id)
                if ok:
                    self.backend.mark_cached(np.asarray([sample_id]),
                                             FORM_CODE[form])
        if (self.has_spill or self.has_hbm) \
                and self.cache.has_pending_evicted():
            self.reconcile_evictions()
        return ok

    def admission_votes(self, form: str, ids) -> np.ndarray:
        """The metadata half of admission for many ids under one lock
        acquisition.  Lets producers skip building expensive values
        (e.g. copying augmented rows out of a batch array) for entries
        the policy would reject anyway; :meth:`admit_batch` re-votes, so
        a stale True here only costs the discarded value, never a wrong
        insert."""
        with self._lock:
            return np.asarray([self.admission.wants(self.backend, int(s),
                                                    form) for s in ids])

    def admit_batch(self, form: str, entries) -> np.ndarray:
        """Batch-granular :meth:`admit`: ``entries`` is a sequence of
        ``(sample_id, value, nbytes)``.

        Same two-phase policy gating and the same per-entry semantics as
        N ``admit`` calls, but with three lock acquisitions per batch
        instead of three per sample: one metadata acquisition for the
        ``wants`` votes, one cache acquisition for the capacity votes +
        inserts (:meth:`TieredCache.insert_batch_gated`), one metadata
        acquisition for the vectorized ``mark_cached``.  Returns one bool
        per entry (True = resident + marked).
        """
        entries = list(entries)
        ok = np.zeros(len(entries), bool)
        if not entries or self.cache.total_capacity(form) == 0:
            return ok
        with self._lock:
            wants = [self.admission.wants(self.backend, sid, form)
                     for sid, _, _ in entries]
        idx = [i for i, w in enumerate(wants) if w]
        if not idx:
            return ok
        inserted = self.cache.insert_batch_gated(
            form, [entries[i] for i in idx], self.admission)
        live = [i for i, ins in zip(idx, inserted) if ins]
        if not live:
            return ok
        with self._lock:
            if self.controller.active:
                # same residency re-validation as admit(): a concurrent
                # resize may have evicted entries between the insert and
                # this deferred mark (metadata->cache lock order)
                resident = self.cache.contains_many(
                    form, [entries[i][0] for i in live])
                live = [i for i, r in zip(live, resident) if r]
            if live:
                self.backend.mark_cached(
                    np.asarray([entries[i][0] for i in live]),
                    FORM_CODE[form])
        ok[live] = True
        if (self.has_spill or self.has_hbm) \
                and self.cache.has_pending_evicted():
            self.reconcile_evictions()
        return ok

    def refill_candidates(self, k: int) -> np.ndarray:
        """Background-refill picks: random storage-resident samples
        (paper step 5: evicted slots repopulate pseudo-randomly)."""
        with self._lock:
            pool = self.backend.storage_pool()
            if not len(pool):
                return pool
            return self.rng.choice(pool, size=min(k, len(pool)),
                                   replace=False)

    def take_refill_work(self, max_n: int = 64) -> np.ndarray:
        """Claim pending eviction slots and return fresh random samples to
        preprocess into them (the paper's background-refill thread body)."""
        with self._lock:
            n = min(len(self._refill_pending), max_n)
            if not n:
                return np.empty(0, np.int64)
            del self._refill_pending[:n]
        return self.refill_candidates(n)

    def lookup(self, sample_id: int):
        return self.cache.lookup(sample_id)

    def lookup_tiered(self, sample_id: int):
        """(form, value, tier) — tier is "hbm" | "dram" | "disk" |
        None, so the pipeline can report per-tier serve bandwidths (an
        "hbm" value is a device-resident ``jax.Array``)."""
        return self.cache.lookup_tiered(sample_id)

    # ------------------------------------------------------------------
    def _remark_keys_locked(self, keys) -> Dict[str, int]:
        """Re-derive ODS status for ``keys`` from actual chain residency
        (most-processed form still holding a copy, or IN_STORAGE).
        Caller holds the metadata lock; the scan takes the cache lock
        nested inside (the service's standard metadata->cache order)."""
        remarked: Dict[str, int] = {}
        regrouped: Dict[Optional[str], list] = {}
        for k, form in zip(keys, self.cache.serving_forms(keys)):
            regrouped.setdefault(form, []).append(k)
        for form, ids in regrouped.items():
            arr = np.asarray(ids, np.int64)
            if form is None:
                self.backend.mark_evicted(arr)
            else:
                self.backend.mark_cached(arr, FORM_CODE[form])
            remarked[form or "storage"] = len(ids)
        return remarked

    def _reconcile_evictions_locked(self) -> Dict[str, int]:
        keys = self.cache.take_evicted()
        if not keys:
            return {}
        return self._remark_keys_locked(sorted(set(keys)))

    def reconcile_evictions(self) -> Dict[str, int]:
        """Patch ODS metadata for keys the tier chains evicted as a side
        effect of serving (spill overflow making room, promotions
        backfilling DRAM, device demotions).  Runs automatically per
        batch and per admit; public for tests and direct-engine
        users."""
        if not (self.has_spill or self.has_hbm):
            return {}
        with self._lock:
            return self._reconcile_evictions_locked()

    def apply_partition(self, partition: mdp.Partition,
                        disk_partition: Optional[mdp.Partition] = None,
                        hbm_partition: Optional[mdp.Partition] = None
                        ) -> Dict[str, int]:
        """Resize the live cache to ``partition`` (and, when configured,
        its disk level to ``disk_partition`` and device level to
        ``hbm_partition``) and patch ODS metadata.

        Keys evicted by shrinking partitions are *demoted*: DRAM
        shrink evictions spill to disk where one exists, and each
        key's status falls back to the most-processed form still
        resident anywhere in its chain, or to IN_STORAGE when nothing
        remains.  The residency scan + metadata patch run under the
        metadata lock (cache lock nested inside, the same
        metadata->cache order ``next_batch_ids`` uses): a concurrent
        ``admit`` marks its status under this lock *after* its insert,
        so the scan either sees the insert or is serialized before the
        re-mark — no stale IN_STORAGE can overwrite a live admission.
        """
        spill_split = None
        if disk_partition is not None and self.has_spill:
            spill_split = (disk_partition.x_e, disk_partition.x_d,
                           disk_partition.x_a)
        elif self.has_spill and self.disk_partition is not None:
            spill_split = (self.disk_partition.x_e,
                           self.disk_partition.x_d,
                           self.disk_partition.x_a)
        hbm_split = None
        if hbm_partition is not None and self.has_hbm:
            hbm_split = (hbm_partition.x_e, hbm_partition.x_d,
                         hbm_partition.x_a)
        elif self.has_hbm and self.hbm_partition is not None:
            hbm_split = (self.hbm_partition.x_e, self.hbm_partition.x_d,
                         self.hbm_partition.x_a)
        evicted = self.cache.resize(
            (partition.x_e, partition.x_d, partition.x_a),
            spill_split=spill_split, hbm_split=hbm_split)
        self.partition = partition
        if disk_partition is not None and self.has_spill:
            self.disk_partition = disk_partition
        if hbm_partition is not None and self.has_hbm:
            self.hbm_partition = hbm_partition
        keys = set().union(*evicted.values()) if evicted else set()
        keys.update(self.cache.take_evicted())
        if not keys:
            return {}
        with self._lock:
            return self._remark_keys_locked(sorted(keys))

    def set_clock(self, clock) -> None:
        """Install a pluggable time source (anything with ``.now()``;
        ``None`` restores wall time).  Under a
        :class:`~repro.workload.clock.VirtualClock` this makes the
        adaptive repartition cooldown count *trace* seconds, so the
        repartition cadence is deterministic instead of tracking host
        CPU speed."""
        self.clock = clock

    def maybe_repartition(self) -> bool:
        """Adaptive-mode tick: cheap no-op unless telemetry-calibrated
        drift warrants a re-solve AND the predicted gain clears the
        hysteresis threshold.  Safe to call from pipeline threads."""
        return self.controller.tick()

    def tier_capacity(self, form: str) -> int:
        """Whole-chain capacity for ``form`` (DRAM + spill): the gate
        pipelines use to decide whether producing/refilling a form can
        possibly land anywhere — must match ``admit``'s own
        total_capacity fast path, or a disk-only form never refills."""
        return self.cache.total_capacity(form)

    def tier_free_bytes(self, form: str) -> int:
        """Whole-chain free bytes for ``form`` (refill top-up sizing)."""
        return self.cache.chain_free_bytes(form)

    # ------------------------------------------------------------------
    def checkpoint_job(self, job_id: int) -> Dict:
        """Epoch-consistent snapshot of one job's sampling state: the
        backend's seen-mask/epoch/served plus the job's EpochSampler
        position (permutation, offset, RNG).  Restoring into a fresh
        session continues exactly-once-per-epoch coverage with zero
        re-preprocessing of already-consumed samples."""
        with self._lock:
            if job_id not in self._samplers:
                raise KeyError(f"job {job_id} is not registered")
            return {
                "format": 1,
                "n_samples": self.cfg.dataset.n_total,
                "batch_size": self._samplers[job_id].bs,
                "backend": self.backend.checkpoint_job(job_id),
                "sampler": self._samplers[job_id].state_dict(),
            }

    def restore_job(self, job_id: int, snap: Dict) -> None:
        """Install a :meth:`checkpoint_job` snapshot on ``job_id`` (a
        re-admitted job's fresh session id is fine — the snapshot fully
        overwrites the new registration's sampler and seen state)."""
        if snap.get("format") != 1:
            raise ValueError(f"unknown snapshot format "
                             f"{snap.get('format')!r}")
        if int(snap["n_samples"]) != self.cfg.dataset.n_total:
            raise ValueError(
                f"snapshot is for a {snap['n_samples']}-sample dataset, "
                f"this service has {self.cfg.dataset.n_total}")
        with self._lock:
            if job_id not in self._samplers:
                raise KeyError(f"job {job_id} is not registered")
            if int(snap["batch_size"]) != self._samplers[job_id].bs:
                raise ValueError(
                    f"snapshot batch_size {snap['batch_size']} != session "
                    f"batch_size {self._samplers[job_id].bs}")
            self._samplers[job_id].load_state_dict(snap["sampler"])
            self.backend.restore_job(job_id, snap["backend"])

    # ------------------------------------------------------------------
    def fail_shard(self, shard: int) -> None:
        """A cache shard died: fail its key range over to storage.

        The shard transport is killed (subsequent per-shard ops degrade
        to misses/drops in the client), and every sample the ring maps
        to the dead shard is re-marked IN_STORAGE so the sampler stops
        treating it as cached; the residency push is invalidated so the
        next batch sees the shrunk ring."""
        kill = getattr(self.cache, "kill_shard", None)
        if kill is None:
            raise ValueError("fail_shard needs a sharded data plane "
                             "(SenecaConfig(shards=N))")
        kill(shard)
        with self._lock:
            n = self.cfg.dataset.n_total
            owned = np.flatnonzero(
                self.cache.router.shard_of_many(np.arange(n)) == shard)
            if len(owned):
                self.backend.mark_evicted(owned)
            self._residency_version = -1
        self.telemetry.record_error("fault.shard-kill")

    def restore_shard(self, shard: int) -> None:
        """Bring a killed shard back (cold: its cache is empty); the
        ring re-expands and admissions repopulate it organically."""
        restart = getattr(self.cache, "restart_shard", None)
        if restart is None:
            raise ValueError("restore_shard needs a sharded data plane "
                             "(SenecaConfig(shards=N))")
        restart(shard)
        with self._lock:
            self._residency_version = -1
        self.telemetry.record_error("recovery.shard-restart")

    # ------------------------------------------------------------------
    def close(self) -> None:
        """Tear down the engine's storage: drops every spill-tier file
        (idempotent; serving after close() re-creates nothing)."""
        self.cache.close()

    def stats(self) -> Dict[str, float]:
        tiers = np.bincount(
            self.cache.status_array(self.cfg.dataset.n_total), minlength=4)
        out = self._spill_stats()
        out.update({
            "partition": self.partition.label,
            "predicted_throughput": self.partition.throughput,
            "backend": self.backend.name,
            "augment_backend": self.augment.name,
            "refill_errors": self.telemetry.error_count("refill"),
            "policies": {"sampler": self.sampler.name,
                         "admission": self.admission.name,
                         "eviction": self.eviction.name},
            "ods_hit_rate": self.backend.hit_rate(),
            "hits": self.backend.hits,
            "misses": self.backend.misses,
            "substitutions": self.backend.substitutions,
            "cache_bytes_used": self.cache.bytes_used(),
            "cache_lookup_hit_rate": self.cache.hit_rate(),
            "tier_counts": {form: int(tiers[FORM_CODE[form]])
                            for form in FORMS},
            "metadata_bytes": self.backend.metadata_bytes(),
            "repartitions": self.controller.summary(),
            "telemetry": self.telemetry.as_dict(),
        })
        shard_stats = getattr(self.cache, "shard_stats", None)
        if shard_stats is not None:
            out["shards"] = shard_stats()
            prod_stats = getattr(self.cache, "production_stats", None)
            if prod_stats is not None:
                sp = prod_stats()
                if sp["led"] or sp["duplicates"]:
                    out["shard_production"] = sp
        # additive: the single-flight table's counters appear only once
        # it has seen traffic, so idle payloads keep their shape
        prod = self.production.stats()
        if prod["led"] or prod["duplicates"]:
            out["production"] = prod
        errors = self.telemetry.as_dict().get("errors", {})
        fault_counts = {k: v for k, v in errors.items()
                        if k.startswith(("fault.", "recovery."))}
        if fault_counts or getattr(self.cache, "failovers", 0):
            out["faults"] = {
                "counts": fault_counts,
                "injected": sum(v for k, v in fault_counts.items()
                                if k.startswith("fault.")),
                "recovered": sum(v for k, v in fault_counts.items()
                                 if k.startswith("recovery.")),
                "shard_failovers": int(getattr(self.cache,
                                               "failovers", 0)),
            }
        return out

    def _spill_stats(self) -> Dict[str, object]:
        """Additive spill/device-tier keys (empty dict without either
        tier so single-tier stats() payloads stay byte-identical; the
        "hbm" residency count and the hbm block only appear when a
        device tier is configured, so spill-only payloads keep their
        historical shape too)."""
        if not (self.has_spill or self.has_hbm):
            return {}
        res = self.cache.residency_array(self.cfg.dataset.n_total)
        counts = np.bincount(res, minlength=4)
        residency = {"storage": int(counts[0]), "disk": int(counts[1]),
                     "dram": int(counts[2])}
        if self.has_hbm:
            residency["hbm"] = int(counts[3])
        out: Dict[str, object] = {}
        if self.has_spill:
            out.update({
                "disk_partition": self.disk_partition.label
                if self.disk_partition else None,
                "disk_bytes_used": self.cache.disk_bytes_used(),
                "spill": self.cache.spill_stats(),
            })
        out["residency_counts"] = residency
        if self.has_hbm:
            out["hbm_partition"] = (self.hbm_partition.label
                                    if self.hbm_partition else None)
            out["hbm_bytes_used"] = self.cache.hbm_bytes_used()
            out["hbm"] = self.cache.hbm_stats()
        return out


class Session:
    """One training job's handle on a shared SenecaServer.

    Owns the job registration: constructing (via ``open_session``) bumps
    the server's ODS job count, ``close()`` (or leaving the ``with`` block)
    drops it — which also lowers the refcount-eviction threshold for the
    remaining sessions.
    """

    def __init__(self, service: SenecaService, job_id: int,
                 batch_size: int, on_close=None):
        self.service = service
        self.job_id = job_id
        self.batch_size = batch_size
        self._on_close = on_close
        self._closed = False

    # ------------------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def epoch(self) -> int:
        return self.service.backend.epoch_of(self.job_id)

    def next_batch_ids(self) -> Tuple[np.ndarray, np.ndarray]:
        if self._closed:
            raise SessionClosed(
                f"session {self.job_id} is closed; open a new one with "
                f"SenecaServer.open_session()")
        return self.service.next_batch_ids(self.job_id)

    def admit(self, sample_id: int, form: str, value, nbytes: int) -> bool:
        # in-flight pipeline workers may race a close(); drop their
        # admissions instead of corrupting the unregistered job's metadata
        if self._closed:
            return False
        return self.service.admit(sample_id, form, value, nbytes)

    def admit_batch(self, form: str, entries) -> np.ndarray:
        """Batch-granular admit (see :meth:`SenecaService.admit_batch`);
        closed sessions drop the whole batch, mirroring :meth:`admit`."""
        if self._closed:
            return np.zeros(len(list(entries)), bool)
        return self.service.admit_batch(form, entries)

    def lookup(self, sample_id: int):
        return self.service.lookup(sample_id)

    def lookup_tiered(self, sample_id: int):
        return self.service.lookup_tiered(sample_id)

    def checkpoint_state(self) -> Dict:
        """Snapshot this job's sampler state (seen-mask, epoch, served
        count, permutation + RNG position).  A preempted job restores it
        into a *new* session via :meth:`restore_state` and keeps
        exactly-once-per-epoch coverage with zero re-preprocessing."""
        if self._closed:
            raise SessionClosed(
                f"session {self.job_id} is closed; snapshot before close")
        return self.service.checkpoint_job(self.job_id)

    def restore_state(self, state: Dict) -> None:
        """Install a :meth:`checkpoint_state` snapshot (same dataset and
        batch size required; the session id may differ)."""
        if self._closed:
            raise SessionClosed(
                f"session {self.job_id} is closed; open a new one with "
                f"SenecaServer.open_session()")
        self.service.restore_job(self.job_id, state)

    def stats(self) -> Dict[str, float]:
        out = self.service.stats()
        out["session"] = {"job_id": self.job_id, "epoch": self.epoch,
                          "batch_size": self.batch_size,
                          "closed": self._closed}
        return out

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self.service.unregister_job(self.job_id)
        if self._on_close is not None:
            self._on_close(self)

    # ------------------------------------------------------------------
    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class SenecaServer:
    """Facade handing out Sessions over one shared cache+sampler service."""

    def __init__(self, cfg: SenecaConfig = None, *, backend=None,
                 sampler=None, admission=None, eviction=None,
                 augment_backend=None,
                 service: Optional[SenecaService] = None):
        if service is None:
            if cfg is None:
                raise ValueError("SenecaServer needs a SenecaConfig "
                                 "(or an existing service=)")
            service = SenecaService(cfg, backend=backend, sampler=sampler,
                                    admission=admission, eviction=eviction,
                                    augment_backend=augment_backend)
        self.service = service
        self._ids = itertools.count()
        self._sessions: Dict[int, Session] = {}
        self._lock = threading.Lock()
        self._closed = False

    # ------------------------------------------------------------------
    @classmethod
    def for_dataset(cls, ds, cache_bytes: Optional[int] = None,
                    cache_frac: float = 0.4,
                    hardware: HardwareProfile = AZURE_NC96,
                    **cfg_kwargs) -> "SenecaServer":
        """Build a server for a :mod:`repro.data.synthetic`-style dataset
        (anything with n_samples / mean_encoded_bytes / decoded_bytes() /
        augmented_bytes()), sizing the cache as a fraction of the
        fully-augmented dataset unless ``cache_bytes`` is given."""
        profile = DatasetProfile(ds.name, ds.n_samples,
                                 ds.mean_encoded_bytes,
                                 decoded_bytes=ds.decoded_bytes(),
                                 augmented_bytes=ds.augmented_bytes())
        if cache_bytes is None:
            cache_bytes = int(cache_frac * ds.n_samples
                              * ds.augmented_bytes())
        return cls(SenecaConfig(cache_bytes=cache_bytes, hardware=hardware,
                                dataset=profile, **cfg_kwargs))

    # ------------------------------------------------------------------
    def open_session(self, batch_size: int, sampler=None) -> Session:
        """Open a job session.  ``sampler`` (None | "zipfian" |
        "phase-shift" | callable) picks this job's request stream — see
        :meth:`SenecaService.register_job`."""
        with self._lock:
            job_id = next(self._ids)
            self.service.register_job(job_id, batch_size,
                                      sampler=sampler)
            sess = Session(self.service, job_id, batch_size,
                           on_close=self._forget)
            self._sessions[job_id] = sess
            return sess

    def _forget(self, sess: Session) -> None:
        with self._lock:
            self._sessions.pop(sess.job_id, None)

    @property
    def n_sessions(self) -> int:
        with self._lock:
            return len(self._sessions)

    @property
    def partition(self):
        return self.service.partition

    def maybe_repartition(self) -> bool:
        """Explicit adaptive tick (see :class:`RepartitionController`);
        the alternative to the ``repartition_period`` background thread."""
        return self.service.maybe_repartition()

    def run_workload(self, trace, storage, *, clock=None,
                     timeout: Optional[float] = None,
                     raise_on_error: bool = True, **runner_kwargs):
        """Run a multi-job trace against this server's shared cache and
        return the :class:`~repro.workload.runner.WorkloadResult`.

        Convenience over :class:`~repro.workload.runner.WorkloadRunner`
        (which see for ``clock=``/``record_ids=``/``seed=`` knobs and
        the deterministic VirtualClock contract); ``timeout`` /
        ``raise_on_error`` are forwarded to
        :meth:`~repro.workload.runner.WorkloadRunner.run`.  Each job in
        ``trace`` opens its own session, so arrivals/departures drive
        the :class:`RepartitionController` exactly like hand-opened
        ones.
        """
        from repro.workload.runner import WorkloadRunner
        runner = WorkloadRunner(self, storage, clock=clock,
                                **runner_kwargs)
        return runner.run(trace, timeout=timeout,
                          raise_on_error=raise_on_error)

    def stats(self) -> Dict[str, float]:
        out = self.service.stats()
        out["n_sessions"] = self.n_sessions
        return out

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        # stop the controller first: the session-close cascade must not
        # trigger re-solves/resizes of a cache that is being torn down
        self.service.controller.stop()
        with self._lock:
            live = list(self._sessions.values())
        try:
            for sess in live:
                sess.close()
        finally:
            # last: drop the spill tier's files (no-leaked-files contract)
            self.service.close()

    # ------------------------------------------------------------------
    def __enter__(self) -> "SenecaServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
