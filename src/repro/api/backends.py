"""ODS metadata backends: one protocol, a NumPy engine and a JAX twin.

The seed shipped two disconnected ODS implementations — the NumPy
:class:`repro.core.ods.ODSState` driving the live service and the jittable
:mod:`repro.core.ods_jax` kernel — with no shared interface.  This module
gives them one: :class:`OdsBackend` is everything the server/sampler layer
needs (job registry, batch substitution, status bookkeeping, admission
value, stats), and ``SenecaServer(backend="jax")`` swaps the fused
``substitute_jit`` path in behind the same session API.

Documented equivalence level (pinned by tests/test_api.py): the two
backends agree on the ODS *invariants* — each job sees every sample once
per epoch, cached-unseen samples are preferred over storage fetches, and
augmented entries evict at refcount == threshold — not on which random
cached sample fills a given slot (the JAX kernel ranks candidates with a
fold-in PRNG instead of ``Generator.choice``; see ods_jax's module doc).

The JAX adapter keeps the authoritative metadata on host (admissions and
evictions arrive from cache worker threads between batches) and stages it
onto the device per substitution call; at real scale the state would live
device-resident behind a donate/update loop, which the protocol already
permits.
"""
from __future__ import annotations

from typing import Dict, Optional, Protocol, Tuple, runtime_checkable

import numpy as np

from repro.core.ods import AUGMENTED, IN_STORAGE, ODSState

__all__ = ["OdsBackend", "NumpyOdsBackend", "JaxOdsBackend",
           "NO_REFCOUNT_EVICT",
           "register_backend", "resolve_backend", "backend_names",
           "AugmentBackend", "NumpyAugmentBackend", "PallasAugmentBackend",
           "register_augment_backend", "resolve_augment_backend",
           "augment_backend_names"]


@runtime_checkable
class OdsBackend(Protocol):
    """Metadata + substitution engine behind a SenecaServer."""

    name: str
    n_samples: int

    # job registry -----------------------------------------------------
    def register_job(self, job_id: int) -> None: ...
    def unregister_job(self, job_id: int) -> None: ...
    @property
    def n_jobs(self) -> int: ...

    # sampling ---------------------------------------------------------
    def sample_batch(self, job_id: int, requested: np.ndarray,
                     evict_threshold: Optional[int] = None
                     ) -> Tuple[np.ndarray, np.ndarray]: ...
    def count_serve(self, batch: np.ndarray) -> None: ...
    def epoch_of(self, job_id: int) -> int: ...

    # cache bookkeeping ------------------------------------------------
    def status_of(self, ids: np.ndarray) -> np.ndarray: ...
    def mark_cached(self, ids: np.ndarray, form: int) -> None: ...
    def mark_evicted(self, ids: np.ndarray) -> None: ...
    def set_residency(self, levels: Optional[np.ndarray]) -> None: ...
    def set_inflight(self, mask: Optional[np.ndarray]) -> None: ...
    def admission_value(self, sample_id: int) -> int: ...
    def storage_pool(self) -> np.ndarray: ...

    # fault tolerance --------------------------------------------------
    def checkpoint_job(self, job_id: int) -> Dict: ...
    def restore_job(self, job_id: int, snap: Dict) -> None: ...

    # stats ------------------------------------------------------------
    @property
    def hits(self) -> int: ...
    @property
    def misses(self) -> int: ...
    @property
    def substitutions(self) -> int: ...
    def hit_rate(self) -> float: ...
    def metadata_bytes(self) -> int: ...


class NumpyOdsBackend:
    """Thin adapter over the vectorized NumPy ODS (the seed engine)."""

    name = "numpy"

    def __init__(self, n_samples: int, seed: int = 0):
        self.state = ODSState.create(n_samples, seed=seed)
        self.n_samples = n_samples

    # job registry -----------------------------------------------------
    def register_job(self, job_id):
        self.state.register_job(job_id)

    def unregister_job(self, job_id):
        self.state.unregister_job(job_id)

    @property
    def n_jobs(self):
        return self.state.n_jobs

    # sampling ---------------------------------------------------------
    def sample_batch(self, job_id, requested, evict_threshold=None):
        return self.state.sample_batch(job_id, requested,
                                       evict_threshold=evict_threshold)

    def count_serve(self, batch):
        cached = self.state.status[batch] != IN_STORAGE
        self.state.hits += int(cached.sum())
        self.state.misses += int(len(batch) - cached.sum())

    def epoch_of(self, job_id):
        return self.state.epoch.get(job_id, 0)

    # cache bookkeeping ------------------------------------------------
    def status_of(self, ids):
        return self.state.status[ids].copy()

    def mark_cached(self, ids, form):
        self.state.mark_cached(np.asarray(ids), form)

    def mark_evicted(self, ids):
        self.state.mark_evicted(np.asarray(ids))

    def set_residency(self, levels):
        self.state.set_residency(levels)

    def set_inflight(self, mask):
        self.state.set_inflight(mask)

    def admission_value(self, sample_id):
        return self.state.admission_value(sample_id)

    def storage_pool(self):
        return np.flatnonzero(self.state.status == IN_STORAGE)

    # fault tolerance --------------------------------------------------
    def checkpoint_job(self, job_id):
        return self.state.checkpoint_job(job_id)

    def restore_job(self, job_id, snap):
        self.state.restore_job(job_id, snap)

    # stats ------------------------------------------------------------
    @property
    def hits(self):
        return self.state.hits

    @property
    def misses(self):
        return self.state.misses

    @property
    def substitutions(self):
        return self.state.substitutions

    def hit_rate(self):
        return self.state.hit_rate()

    def metadata_bytes(self):
        return self.state.metadata_bytes()


# threshold meaning "never evict on refcount"; for the jit'd kernel it is
# a static argument, so the sentinel compiles once
NO_REFCOUNT_EVICT = 1 << 30


class JaxOdsBackend:
    """Runs batch substitution through the fused ``ods_jax.substitute_jit``
    kernel while keeping per-job seen/served/epoch plus the shared
    status/refcount tables authoritative on host."""

    name = "jax"

    def __init__(self, n_samples: int, seed: int = 0):
        import jax  # the repo's toolchain bakes jax in; fail loud if not
        self._jax = jax
        from repro.core import ods_jax
        self._ods_jax = ods_jax
        self.n_samples = n_samples
        self.status = np.zeros(n_samples, np.uint8)
        self.refcount = np.zeros(n_samples, np.int32)
        self.seen: Dict[int, np.ndarray] = {}
        self.served: Dict[int, int] = {}
        self.epoch: Dict[int, int] = {}
        self._key = jax.random.key(seed)
        self._residency: Optional[np.ndarray] = None
        self._inflight: Optional[np.ndarray] = None
        self._hits = 0
        self._misses = 0
        self._substitutions = 0

    # job registry -----------------------------------------------------
    def register_job(self, job_id):
        self.seen[job_id] = np.zeros(self.n_samples, bool)
        self.served[job_id] = 0
        self.epoch[job_id] = 0

    def unregister_job(self, job_id):
        self.seen.pop(job_id, None)
        self.served.pop(job_id, None)
        self.epoch.pop(job_id, None)

    @property
    def n_jobs(self):
        return max(len(self.seen), 1)

    # sampling ---------------------------------------------------------
    def sample_batch(self, job_id, requested, evict_threshold=None):
        import jax.numpy as jnp
        thr = int(evict_threshold) if evict_threshold is not None \
            else self.n_jobs
        requested = np.asarray(requested)
        B = len(requested)
        # mirror the kernel's rollover predicate so host epoch counting
        # stays in lockstep with the device-side seen/served reset
        if self.n_samples - self.served[job_id] < B:
            self.epoch[job_id] += 1
        pre_status = self.status
        pre_seen = self.seen[job_id]
        state = self._ods_jax.ODSJaxState(
            status=jnp.asarray(self.status),
            refcount=jnp.asarray(self.refcount),
            seen=jnp.asarray(pre_seen),
            served=jnp.asarray(self.served[job_id], jnp.int32))
        self._key, sub = self._jax.random.split(self._key)
        # the coalescing table's in-flight mask routes to separate
        # jitted variants; with the mask absent (coalescing off or
        # table idle) the historical kernels — and their exact draw
        # sequences — run untouched
        infl = self._inflight
        if infl is not None and not infl.any():
            infl = None
        if self._residency is not None:
            # two-level cache: the residency-ranked kernel (DRAM-unseen
            # candidates outrank disk-unseen ones outrank storage)
            if infl is not None:
                state, batch, evict_mask = \
                    self._ods_jax.substitute_tiered_inflight_jit(
                        state, jnp.asarray(requested), sub, thr,
                        jnp.asarray(self._residency), jnp.asarray(infl))
            else:
                state, batch, evict_mask = \
                    self._ods_jax.substitute_tiered_jit(
                        state, jnp.asarray(requested), sub, thr,
                        jnp.asarray(self._residency))
        elif infl is not None:
            state, batch, evict_mask = self._ods_jax.substitute_inflight_jit(
                state, jnp.asarray(requested), sub, thr, jnp.asarray(infl))
        else:
            state, batch, evict_mask = self._ods_jax.substitute_jit(
                state, jnp.asarray(requested), sub, thr)
        batch = np.asarray(batch)
        cached = pre_status[batch] != IN_STORAGE
        self._hits += int(cached.sum())
        self._misses += int(B - cached.sum())
        direct = (pre_status[requested] != IN_STORAGE) & ~pre_seen[requested]
        self._substitutions += int(np.count_nonzero(
            ~direct & (pre_status[requested] == IN_STORAGE) & cached))
        # np.array (not asarray): device buffers view as read-only, and the
        # host copies take writes from mark_cached / mark_evicted
        self.status = np.array(state.status)
        self.refcount = np.array(state.refcount)
        self.seen[job_id] = np.array(state.seen)
        self.served[job_id] = int(state.served)
        return batch, np.flatnonzero(np.asarray(evict_mask))

    def count_serve(self, batch):
        cached = self.status[batch] != IN_STORAGE
        self._hits += int(cached.sum())
        self._misses += int(len(batch) - cached.sum())

    def epoch_of(self, job_id):
        return self.epoch.get(job_id, 0)

    # cache bookkeeping ------------------------------------------------
    def status_of(self, ids):
        return self.status[ids].copy()

    def mark_cached(self, ids, form):
        ids = np.asarray(ids)
        self.status[ids] = form
        if form == AUGMENTED:
            # same semantics as ODSState.mark_cached: start the refcount at
            # the number of jobs that already consumed the sample so the
            # threshold still fires after the remaining jobs use it
            count = np.zeros(len(ids), np.int32)
            for bits in self.seen.values():
                count += bits[ids].astype(np.int32)
            self.refcount[ids] = count

    def mark_evicted(self, ids):
        ids = np.asarray(ids)
        self.status[ids] = IN_STORAGE
        self.refcount[ids] = 0

    def set_residency(self, levels):
        self._residency = levels

    def set_inflight(self, mask):
        self._inflight = mask

    def admission_value(self, sample_id):
        return self.n_jobs - int(sum(bits[sample_id]
                                     for bits in self.seen.values()))

    def storage_pool(self):
        return np.flatnonzero(self.status == IN_STORAGE)

    # fault tolerance --------------------------------------------------
    def checkpoint_job(self, job_id):
        """Same contract as :meth:`ODSState.checkpoint_job` — seen mask,
        epoch, served; the fold-in key is recorded for inspection but
        not restored (it is shared across jobs)."""
        if job_id not in self.seen:
            raise KeyError(f"job {job_id} is not registered")
        return {
            "n_samples": self.n_samples,
            "seen": np.packbits(self.seen[job_id]),
            "epoch": int(self.epoch[job_id]),
            "served": int(self.served[job_id]),
            "substitutions": int(self._substitutions),
            "rng_state": np.asarray(
                self._jax.random.key_data(self._key)).tolist(),
        }

    def restore_job(self, job_id, snap):
        if int(snap["n_samples"]) != self.n_samples:
            raise ValueError(
                f"snapshot is for a {snap['n_samples']}-sample dataset, "
                f"this one has {self.n_samples}")
        if job_id not in self.seen:
            raise KeyError(f"job {job_id} is not registered")
        self.seen[job_id] = np.unpackbits(
            np.asarray(snap["seen"], np.uint8),
            count=self.n_samples).astype(bool)
        self.epoch[job_id] = int(snap["epoch"])
        self.served[job_id] = int(snap["served"])

    # stats ------------------------------------------------------------
    @property
    def hits(self):
        return self._hits

    @property
    def misses(self):
        return self._misses

    @property
    def substitutions(self):
        return self._substitutions

    def hit_rate(self):
        total = self._hits + self._misses
        return self._hits / total if total else 0.0

    def metadata_bytes(self):
        return self.n_samples * len(self.seen) // 8 + self.n_samples


# ----------------------------------------------------------------------
# augment backends: the batched-transform twin of the ODS backend knob.
# The stage-parallel DSIPipeline executor hands its augment stage a whole
# group of decoded samples at once; which engine runs the pixel math is
# selected here (SenecaConfig.augment_backend / SenecaServer kwarg).
@runtime_checkable
class AugmentBackend(Protocol):
    """Vectorized augmentation over a batch of decoded uint8 images.

    ``augment_batch(images, crop_hw, seeds)`` takes (B,H,W,3) uint8 and
    per-sample integer seeds and returns (B,ch,cw,3) float32.  Both
    implementations derive the crop/flip parameters from the same
    per-seed draw sequence (repro.data.augment.crop_flip_params), so the
    transform is deterministic per *sample id*, not per batch
    composition — swapping backends changes throughput, not content
    (within float tolerance).
    """

    name: str

    def augment_batch(self, images: np.ndarray, crop_hw: Tuple[int, int],
                      seeds: np.ndarray) -> np.ndarray: ...


class NumpyAugmentBackend:
    """Host-CPU fallback: the per-sample augment_np loop (paper-faithful
    placement; no jax required)."""

    name = "numpy"

    def augment_batch(self, images, crop_hw, seeds):
        from repro.data.augment import augment_batch_np
        return augment_batch_np(images, crop_hw, seeds)


class PallasAugmentBackend:
    """Fused Pallas crop+flip+normalize kernel (repro.kernels.augment):
    interpret mode off-TPU, compiled Mosaic on TPU.  Parameters are
    derived on host from the same per-sample seeds as the NumPy path."""

    name = "pallas"

    def __init__(self, interpret: Optional[bool] = None):
        import jax  # baked into the toolchain; fail loud if absent
        import jax.numpy as jnp
        from repro.kernels.augment.ops import augment_batch_seeded
        self._jnp = jnp
        self._augment = augment_batch_seeded
        self._interpret = interpret
        self._size_counts: Dict[int, int] = {}
        del jax

    def augment_batch(self, images, crop_hw, seeds):
        images = np.asarray(images)
        # recurring group sizes (typically the full batch) earn an
        # exact-size kernel trace — padding a 12-sample batch to 16
        # forever would waste 33% augment work; one-off ragged sizes
        # still share the power-of-two buckets
        B = len(images)
        self._size_counts[B] = self._size_counts.get(B, 0) + 1
        bucket = B if self._size_counts[B] >= 2 else None
        out = self._augment(images, np.asarray(seeds),
                            crop_hw[0], crop_hw[1],
                            out_dtype=self._jnp.float32,
                            interpret=self._interpret, bucket=bucket)
        return np.asarray(out, np.float32)


_AUGMENT_BACKENDS: Dict[str, type] = {
    "numpy": NumpyAugmentBackend,
    "pallas": PallasAugmentBackend,
    # alias: the ODS knob calls its jittable engine "jax"; accept the
    # same spelling here
    "jax": PallasAugmentBackend,
}


def register_augment_backend(name: str, factory: type) -> None:
    _AUGMENT_BACKENDS[name] = factory


def augment_backend_names() -> Tuple[str, ...]:
    return tuple(sorted(_AUGMENT_BACKENDS))


def resolve_augment_backend(spec):
    """Name or instance -> AugmentBackend."""
    if isinstance(spec, str):
        try:
            return _AUGMENT_BACKENDS[spec]()
        except KeyError:
            raise ValueError(
                f"unknown augment backend {spec!r}; registered: "
                f"{augment_backend_names()}") from None
    if not isinstance(spec, AugmentBackend):
        raise TypeError(f"{spec!r} does not implement AugmentBackend")
    return spec


_BACKENDS: Dict[str, type] = {"numpy": NumpyOdsBackend, "jax": JaxOdsBackend}


def register_backend(name: str, factory: type) -> None:
    _BACKENDS[name] = factory


def backend_names() -> Tuple[str, ...]:
    return tuple(sorted(_BACKENDS))


def resolve_backend(spec, n_samples: int, seed: int = 0):
    """Name or instance -> OdsBackend for ``n_samples``."""
    if isinstance(spec, str):
        try:
            return _BACKENDS[spec](n_samples, seed=seed)
        except KeyError:
            raise ValueError(f"unknown ODS backend {spec!r}; registered: "
                             f"{backend_names()}") from None
    if not isinstance(spec, OdsBackend):
        raise TypeError(f"{spec!r} does not implement OdsBackend")
    return spec
