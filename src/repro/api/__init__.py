"""repro.api — the single public surface of the Seneca reproduction.

Live service (sessions over one shared cache + sampler)::

    from repro.api import SenecaServer

    server = SenecaServer.for_dataset(ds, cache_frac=0.35)
    with server.open_session(batch_size=32) as sess:
        ids, forms = sess.next_batch_ids()

Pluggable behavior: ``SenecaServer(cfg, backend="jax")`` swaps the ODS
metadata engine; ``sampler=`` / ``admission=`` / ``eviction=`` select
policies by registered name ("ods"/"naive", "unseen-only"/"capacity",
"refcount"/"lru"); :func:`register_policy` adds new ones.

Live repartitioning: ``SenecaConfig(repartition="adaptive")`` turns on
the telemetry-calibrated :class:`RepartitionController` — pipelines
report stage timings into :class:`TelemetryAggregator`, the controller
re-solves the MDP on the calibrated profile and resizes the cache split
in place when the predicted gain clears hysteresis (docs/API.md
"Telemetry + adaptive repartitioning").

The fluid-flow simulator behind the paper-figure benchmarks is re-exported
here too, so benchmark and example code imports one namespace only.  See
docs/API.md for the full tour.
"""
from repro.api.backends import (AugmentBackend, JaxOdsBackend,
                                NumpyAugmentBackend, NumpyOdsBackend,
                                OdsBackend, PallasAugmentBackend,
                                augment_backend_names, backend_names,
                                register_augment_backend, register_backend,
                                resolve_augment_backend, resolve_backend)
from repro.api.policies import (AdmissionPolicy, CapacityAdmission,
                                EvictionPolicy, LruEviction, NaiveSampler,
                                OdsSampler, RefcountEviction, SamplerPolicy,
                                UnseenOnlyAdmission, policy_names,
                                register_policy, resolve_policy)
from repro.api.server import (CODE_FORM, FORM_CODE, SLO,
                              RepartitionController, SenecaConfig,
                              SenecaServer, SenecaService, Session,
                              SessionClosed)
from repro.api.telemetry import (Ewma, TelemetryAggregator,
                                 TelemetrySnapshot)
# hardware / dataset profiles + the closed-form DSI model (Eqs. 1-9,
# plus the form×tier two-level variant behind the SSD spill engine)
from repro.core.perf_model import (AWS_P3, AZURE_NC96, DATASETS,
                                   EVAL_PROFILES, GB, Gbit, IMAGENET_1K,
                                   IMAGENET_22K, IN_HOUSE, KB, MB,
                                   OPENIMAGES, VALIDATION_PROFILES,
                                   DatasetProfile, HardwareProfile,
                                   JobProfile, dsi_throughput,
                                   dsi_throughput_tiered)
# mechanistic simulator (Table 7 loader matrix) for the fig* benchmarks
from repro.sim.desim import (ALL_LOADERS, DALI_CPU, DALI_GPU, DSISimulator,
                             LoaderSpec, MDP_ONLY, MINIO, PYTORCH, QUIVER,
                             SENECA, SHADE, SimJob, SimResult)
# sharded data plane (docs/API.md "Sharded data plane"): consistent-hash
# router + per-shard caches behind sim/process transports, selected via
# SenecaConfig(shards=N, shard_transport=...)
from repro.service import (CacheShard, ShardConfig, ShardedCache,
                           ShardRouter)
# fault injection + failover (docs/API.md "Fault tolerance & elasticity")
from repro.faults import (FAULT_KINDS, FaultInjector, FaultSpec,
                          LivenessRegistry)

# live multi-job workload runner + pluggable clocks (docs/API.md
# "Multi-job workloads"); VirtualClock makes concurrency deterministic.
# These are re-exported lazily (PEP 562): repro.workload.runner imports
# the pipeline, which imports repro.api.server, which initializes this
# package — an eager import here would close that cycle on a partially
# initialized module.
_WORKLOAD_EXPORTS = ("Clock", "JobResult", "JobSpec", "RealClock",
                     "VirtualClock", "WorkloadResult", "WorkloadRunner",
                     "deterministic_runner",
                     # open-loop serving (docs/API.md "Open-loop serving
                     # & SLOs")
                     "OpenLoopGenerator", "RequestResult", "ServeResult",
                     "ARRIVAL_PROCESSES", "poisson_arrivals",
                     "bursty_arrivals", "diurnal_arrivals",
                     "make_arrivals")


def __getattr__(name: str):
    if name in _WORKLOAD_EXPORTS:
        import repro.workload as _workload
        return getattr(_workload, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    # server / session facade
    "SenecaServer", "Session", "SessionClosed", "SenecaConfig",
    "SenecaService", "SLO", "FORM_CODE", "CODE_FORM",
    # telemetry + adaptive repartitioning
    "RepartitionController", "TelemetryAggregator", "TelemetrySnapshot",
    "Ewma",
    # policies
    "SamplerPolicy", "AdmissionPolicy", "EvictionPolicy",
    "OdsSampler", "NaiveSampler", "UnseenOnlyAdmission",
    "CapacityAdmission", "RefcountEviction", "LruEviction",
    "register_policy", "resolve_policy", "policy_names",
    # backends
    "OdsBackend", "NumpyOdsBackend", "JaxOdsBackend",
    "register_backend", "resolve_backend", "backend_names",
    "AugmentBackend", "NumpyAugmentBackend", "PallasAugmentBackend",
    "register_augment_backend", "resolve_augment_backend",
    "augment_backend_names",
    # profiles + closed-form model
    "HardwareProfile", "DatasetProfile", "JobProfile", "dsi_throughput",
    "dsi_throughput_tiered",
    "AZURE_NC96", "AWS_P3", "IN_HOUSE", "VALIDATION_PROFILES",
    "EVAL_PROFILES", "DATASETS", "IMAGENET_1K", "IMAGENET_22K",
    "OPENIMAGES", "GB", "MB", "KB", "Gbit",
    # simulator
    "DSISimulator", "LoaderSpec", "SimJob", "SimResult", "ALL_LOADERS",
    "PYTORCH", "DALI_CPU", "DALI_GPU", "MINIO", "QUIVER", "SHADE",
    "MDP_ONLY", "SENECA",
    # live multi-job workloads
    "WorkloadRunner", "JobSpec", "JobResult", "WorkloadResult",
    "Clock", "RealClock", "VirtualClock", "deterministic_runner",
    # open-loop serving
    "OpenLoopGenerator", "RequestResult", "ServeResult",
    "ARRIVAL_PROCESSES", "poisson_arrivals", "bursty_arrivals",
    "diurnal_arrivals", "make_arrivals",
    # sharded data plane
    "ShardRouter", "ShardedCache", "CacheShard", "ShardConfig",
    # fault injection + failover
    "FaultSpec", "FaultInjector", "LivenessRegistry", "FAULT_KINDS",
]
