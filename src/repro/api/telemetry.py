"""Runtime telemetry for the DSI pipeline (the measurement half of
adaptive repartitioning).

The MDP's performance model (Seneca §5.1) is parameterized by Table-3
hardware rates; on any real deployment the observed rates drift — CPU
contention from concurrent jobs, storage throttling, differently sized
samples.  :class:`TelemetryAggregator` is the shared sink every
:class:`~repro.data.pipeline.DSIPipeline` worker reports into:

* per-stage latency EWMAs (``fetch_storage`` / ``fetch_cache`` /
  ``decode`` / ``augment`` / ``collate``), per *sample*;
* per-transfer effective bandwidth EWMAs for the storage and cache
  channels (bytes/s, stall time included);
* per-form serve counts (which tier answered each lookup);
* bounded-queue depth/occupancy gauges from the stage-parallel executor
  (ingestion backpressure: a queue pinned at 1.0 occupancy names the
  stage the repartition controller should be feeding);
* error counters (``refill`` / ``prefetch`` / ...) so background-thread
  failures surface in ``stats()`` instead of vanishing.

:meth:`snapshot` folds these into a :class:`TelemetrySnapshot` whose
``t_da`` / ``t_a`` / ``b_storage`` / ``b_cache`` fields line up with the
:class:`~repro.core.perf_model.HardwareProfile` fields of the same name —
:func:`repro.core.perf_model.calibrate` swaps them in, and the
:class:`~repro.api.server.RepartitionController` re-runs MDP on the
calibrated profile.

Thread-safety: one lock around all mutation; every reporter (pipeline
fetch/decode/augment workers, refill threads) shares one aggregator per
:class:`~repro.api.server.SenecaService`.

Notes on estimator semantics:

* CPU rates are *node-aggregate* samples/s: per-sample latency EWMAs are
  scaled by the registered worker concurrency (``add_concurrency`` /
  ``remove_concurrency``, called by pipelines on start/stop), mirroring
  how Table 3 measures t_DA with all cores busy.  A stage can report its
  own worker count (``record_stage(..., workers=)``) when not every
  registered worker runs it — the stage-parallel executor's augment
  stage is a single thread, its decode group is elastically sized — and
  the aggregate rate then uses the per-stage counts (pipelined:
  ``t_da = min(w_dec/dec, w_aug/aug)``) instead of the global scale.
* Bandwidths are per-transfer effective rates.  Under a shared
  token-bucket (``RemoteStorage``) each transfer already observes its
  contended share, so the EWMA approximates the per-stream bandwidth and
  is deliberately *not* multiplied by concurrency.
"""
from __future__ import annotations

import math
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

STAGES = ("fetch_storage", "fetch_cache", "decode", "augment", "collate")
#: "h2d" is the host→device transfer channel: its EWMA calibrates the
#: device tier's ``b_hbm`` and its cumulative byte counter is the
#: zero-copy assertion surface (an all-HBM-hit epoch moves no h2d bytes)
CHANNELS = ("storage", "cache", "disk", "h2d")

#: open-loop per-request phase breakdown (queue wait + data-path stages)
REQUEST_PHASES = ("queue", "fetch", "decode", "augment")
#: request outcomes: "served" (full quality), "degraded" (augment
#: skipped, decoded form), "encoded" (decode+augment skipped), "shed"
#: (rejected at admission — counted, never silently dropped)
REQUEST_OUTCOMES = ("served", "degraded", "encoded", "shed")


def quantile(samples: Sequence[float], q: float) -> float:
    """Exact nearest-rank quantile: the smallest sample x such that at
    least ``ceil(q * n)`` samples are <= x.  No interpolation — p99 of a
    latency set is always a latency that actually occurred, and the
    result is bit-reproducible across runs (the property the
    VirtualClock determinism tests assert on)."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile q must be in [0, 1], got {q}")
    if len(samples) == 0:
        raise ValueError("quantile of an empty sample set")
    xs = sorted(samples)
    k = max(math.ceil(q * len(xs)), 1)
    return xs[min(k, len(xs)) - 1]


class Ewma:
    """Exponentially weighted moving average with an observation count."""

    def __init__(self, alpha: float = 0.2):
        self.alpha = float(alpha)
        self.value: Optional[float] = None
        self.n = 0

    def update(self, x: float) -> None:
        x = float(x)
        self.value = x if self.value is None \
            else self.alpha * x + (1.0 - self.alpha) * self.value
        self.n += 1

    def __repr__(self) -> str:
        return f"Ewma(value={self.value}, n={self.n})"


@dataclass(frozen=True)
class TelemetrySnapshot:
    """Point-in-time read of the aggregator (all derived values pure).

    ``t_da`` / ``t_a`` / ``b_storage`` / ``b_cache`` are ``None`` until the
    underlying signals exist; counts let :func:`perf_model.calibrate`
    apply a min-samples floor per field.
    """
    stage_latency: Dict[str, Optional[float]]   # EWMA seconds/sample
    stage_n: Dict[str, int]
    bandwidth: Dict[str, Optional[float]]       # EWMA bytes/s per channel
    bandwidth_n: Dict[str, int]
    serve_counts: Dict[str, int]                # per-form + "storage"
    concurrency: int
    queue_depth: Dict[str, float] = field(default_factory=dict)
    queue_occupancy: Dict[str, float] = field(default_factory=dict)
    errors: Dict[str, int] = field(default_factory=dict)
    t_da: Optional[float] = None                # samples/s, decode+augment
    t_a: Optional[float] = None                 # samples/s, augment-only
    b_storage: Optional[float] = None           # bytes/s
    b_cache: Optional[float] = None             # bytes/s (DRAM hits)
    b_disk: Optional[float] = None              # bytes/s (spill-tier hits)
    b_hbm: Optional[float] = None               # bytes/s (h2d transfers)
    counts: Dict[str, int] = field(default_factory=dict)  # per calibration field
    channel_bytes: Dict[str, int] = field(default_factory=dict)  # cumulative

    @property
    def n_serves(self) -> int:
        return sum(self.serve_counts.values())

    def hit_rates(self) -> Dict[str, float]:
        """Fraction of lookups answered by each tier ('storage' = miss)."""
        total = self.n_serves
        if not total:
            return {k: 0.0 for k in self.serve_counts}
        return {k: v / total for k, v in self.serve_counts.items()}


class TelemetryAggregator:
    """Thread-safe sink for pipeline stage timings, transfer bandwidths
    and per-form serve counts; snapshots feed ``perf_model.calibrate``."""

    def __init__(self, alpha: float = 0.2):
        self._lock = threading.Lock()
        self._alpha = float(alpha)
        self._stages: Dict[str, Ewma] = {s: Ewma(alpha) for s in STAGES}
        self._bw: Dict[str, Ewma] = {c: Ewma(alpha) for c in CHANNELS}
        self._channel_bytes: Dict[str, int] = {c: 0 for c in CHANNELS}
        self._serves: Dict[str, int] = {
            "encoded": 0, "decoded": 0, "augmented": 0, "storage": 0}
        self._concurrency = 0
        self._queue_depth: Dict[str, Ewma] = {}
        self._queue_occ: Dict[str, Ewma] = {}
        self._errors: Dict[str, int] = {}
        self._stage_workers: Dict[str, int] = {}
        # open-loop request accounting: outcome counters + raw latency
        # samples (exact percentiles need the full set, not an EWMA).
        # Bounded so an unbounded serve run cannot grow memory without
        # limit; drops beyond the cap are counted, not silent.
        self._req_counts: Dict[str, int] = {o: 0 for o in REQUEST_OUTCOMES}
        self._req_total: List[float] = []
        self._req_phase: Dict[str, List[float]] = {
            p: [] for p in REQUEST_PHASES}
        self._req_cap = 200_000
        self._req_dropped = 0
        # single-flight coalescing: productions avoided by joining
        # another job's in-flight production, and the time spent waiting
        self._coalesced = 0
        self._coalesce_wait_s = 0.0

    # -- reporting (pipeline side) -------------------------------------
    def add_concurrency(self, n: int) -> None:
        with self._lock:
            self._concurrency += int(n)

    def remove_concurrency(self, n: int) -> None:
        with self._lock:
            self._concurrency = max(0, self._concurrency - int(n))

    def record_stage(self, stage: str, seconds: float, n: int = 1,
                     workers: Optional[int] = None) -> None:
        """Record ``n`` samples taking ``seconds`` total in ``stage``.

        ``workers`` declares how many threads run this stage when that
        differs from the registered global concurrency (the per-sample
        executor's pool runs every stage on every worker; the
        stage-parallel executor's stages have their own group sizes).
        Last writer wins — an approximation when executors mix on one
        service.
        """
        if n <= 0 or stage not in self._stages:
            return
        with self._lock:
            self._stages[stage].update(seconds / n)
            if workers is not None:
                self._stage_workers[stage] = max(int(workers), 1)

    def record_bytes(self, channel: str, nbytes: int,
                     seconds: float) -> None:
        """Record one transfer: ``nbytes`` moved in ``seconds``.  Also
        accumulates the channel's total byte counter (the "h2d" total is
        how the device pipeline proves an all-HBM-hit epoch shipped zero
        per-batch host→device bytes)."""
        if channel not in self._bw or nbytes <= 0:
            return
        with self._lock:
            # floor on the denominator: an in-memory hit can measure ~0s
            self._bw[channel].update(nbytes / max(seconds, 1e-9))
            self._channel_bytes[channel] += int(nbytes)

    def channel_total_bytes(self, channel: str) -> int:
        """Cumulative bytes recorded on ``channel`` since construction."""
        with self._lock:
            return self._channel_bytes.get(channel, 0)

    def record_serve(self, form: Optional[str]) -> None:
        """Which tier answered a lookup (None = storage fetch)."""
        key = form if form in self._serves else "storage"
        with self._lock:
            self._serves[key] += 1

    def record_queue(self, name: str, depth: int, capacity: int) -> None:
        """Gauge one bounded pipeline queue: current depth + occupancy
        (depth/capacity).  Occupancy ~1.0 means the downstream stage is
        the bottleneck (ingestion backpressure)."""
        with self._lock:
            if name not in self._queue_depth:
                self._queue_depth[name] = Ewma(self._alpha)
                self._queue_occ[name] = Ewma(self._alpha)
            self._queue_depth[name].update(depth)
            self._queue_occ[name].update(depth / max(capacity, 1))

    def clear_stage_workers(self, *stages: str) -> None:
        """Forget per-stage worker counts (a stopped stage-parallel
        executor must not leave its group sizes scaling latencies that a
        per-sample pipeline reports afterwards)."""
        with self._lock:
            for stage in stages or tuple(self._stage_workers):
                self._stage_workers.pop(stage, None)

    def record_coalesced(self, wait_s: float) -> None:
        """Count one production avoided by joining an in-flight one
        (single-flight coalescing), with the wall/virtual seconds the
        joiner spent waiting for the leader's hand-off."""
        with self._lock:
            self._coalesced += 1
            self._coalesce_wait_s += max(float(wait_s), 0.0)

    def record_error(self, kind: str) -> int:
        """Count one background failure; returns the new total for
        ``kind`` (callers log the first occurrence only)."""
        with self._lock:
            self._errors[kind] = self._errors.get(kind, 0) + 1
            return self._errors[kind]

    def error_count(self, kind: str) -> int:
        with self._lock:
            return self._errors.get(kind, 0)

    # -- open-loop request accounting ----------------------------------
    def record_request(self, outcome: str, total_s: Optional[float] = None,
                       phases: Optional[Dict[str, float]] = None) -> None:
        """Account one open-loop request.  ``outcome`` is one of
        :data:`REQUEST_OUTCOMES`; shed requests carry no latency.
        ``phases`` maps :data:`REQUEST_PHASES` names to seconds spent in
        each (missing phases — e.g. no decode on an augmented hit — are
        simply absent from that request's breakdown)."""
        if outcome not in self._req_counts:
            raise ValueError(f"unknown request outcome {outcome!r}; "
                             f"expected one of {REQUEST_OUTCOMES}")
        with self._lock:
            self._req_counts[outcome] += 1
            if total_s is None:
                return
            if len(self._req_total) >= self._req_cap:
                self._req_dropped += 1
                return
            self._req_total.append(float(total_s))
            if phases:
                for p, dt in phases.items():
                    if p in self._req_phase:
                        self._req_phase[p].append(float(dt))

    def request_summary(self) -> Dict[str, object]:
        """Outcome counters + exact latency percentiles (p50/p99/p999,
        per-phase p50/p99).  Empty-latency runs report counters only."""
        with self._lock:
            counts = dict(self._req_counts)
            total = list(self._req_total)
            phases = {p: list(v) for p, v in self._req_phase.items() if v}
            dropped = self._req_dropped
        out: Dict[str, object] = {
            "outcomes": counts,
            "completed": sum(v for k, v in counts.items() if k != "shed"),
            "latency_samples": len(total),
            "latency_samples_dropped": dropped,
        }
        if total:
            out["latency_s"] = {"p50": quantile(total, 0.50),
                                "p99": quantile(total, 0.99),
                                "p999": quantile(total, 0.999),
                                "mean": sum(total) / len(total),
                                "max": max(total)}
            out["phase_latency_s"] = {
                p: {"p50": quantile(v, 0.50), "p99": quantile(v, 0.99)}
                for p, v in phases.items()}
        return out

    # -- reading (controller side) -------------------------------------
    def snapshot(self) -> TelemetrySnapshot:
        with self._lock:
            lat = {s: e.value for s, e in self._stages.items()}
            lat_n = {s: e.n for s, e in self._stages.items()}
            bw = {c: e.value for c, e in self._bw.items()}
            bw_n = {c: e.n for c, e in self._bw.items()}
            serves = dict(self._serves)
            conc = max(self._concurrency, 1)
            q_depth = {k: e.value for k, e in self._queue_depth.items()
                       if e.value is not None}
            q_occ = {k: e.value for k, e in self._queue_occ.items()
                     if e.value is not None}
            errors = dict(self._errors)
            sw = dict(self._stage_workers)
            ch_bytes = dict(self._channel_bytes)

        def rate(total_latency: Optional[float]) -> Optional[float]:
            if not total_latency or total_latency <= 0:
                return None
            return conc / total_latency

        dec, aug = lat["decode"], lat["augment"]
        w_dec, w_aug = sw.get("decode"), sw.get("augment")
        if dec and aug and (w_dec or w_aug):
            # stage-parallel reporters: decode and augment run on their
            # own worker groups, pipelined — the chain rate is the
            # slower stage's, not conc/(dec+aug)
            t_da = min((w_dec or conc) / dec, (w_aug or conc) / aug)
        else:
            t_da = rate((dec + aug) if dec is not None and aug is not None
                        else None)
        t_a = (w_aug / aug) if aug and w_aug else rate(aug)
        counts = {
            "t_da": min(lat_n["decode"], lat_n["augment"]),
            "t_a": lat_n["augment"],
            "b_storage": bw_n["storage"],
            "b_cache": bw_n["cache"],
            "b_disk": bw_n["disk"],
            "b_hbm": bw_n["h2d"],
        }
        return TelemetrySnapshot(
            stage_latency=lat, stage_n=lat_n, bandwidth=bw,
            bandwidth_n=bw_n, serve_counts=serves, concurrency=conc,
            queue_depth=q_depth, queue_occupancy=q_occ, errors=errors,
            t_da=t_da, t_a=t_a,
            b_storage=bw["storage"], b_cache=bw["cache"],
            b_disk=bw["disk"], b_hbm=bw["h2d"], counts=counts,
            channel_bytes=ch_bytes)

    def as_dict(self) -> Dict[str, object]:
        """JSON-friendly summary for ``stats()`` surfaces.  The
        ``"requests"`` key is additive: present only once open-loop
        requests have been recorded, so closed-loop stats payloads are
        unchanged."""
        snap = self.snapshot()
        with self._lock:
            any_requests = any(self._req_counts.values())
            coalesced = self._coalesced
            coalesce_wait_s = self._coalesce_wait_s
        out = {
            "stage_latency_s": {k: v for k, v in snap.stage_latency.items()
                                if v is not None},
            "bandwidth_bps": {k: v for k, v in snap.bandwidth.items()
                              if v is not None},
            "serve_counts": dict(snap.serve_counts),
            "hit_rates": snap.hit_rates(),
            "concurrency": snap.concurrency,
            "queue_depth": dict(snap.queue_depth),
            "queue_occupancy": dict(snap.queue_occupancy),
            "errors": dict(snap.errors),
            "t_da": snap.t_da, "t_a": snap.t_a,
            "b_storage": snap.b_storage, "b_cache": snap.b_cache,
            "b_disk": snap.b_disk, "b_hbm": snap.b_hbm,
            "channel_bytes": dict(snap.channel_bytes),
        }
        if any_requests:
            out["requests"] = self.request_summary()
        # additive like "requests": present only once coalescing has
        # actually deduped a production, so existing payloads and their
        # consumers are unchanged
        if coalesced:
            out["coalesced"] = coalesced
            out["coalesce_wait_s"] = coalesce_wait_s
        return out
