"""Pluggable Seneca policies: sampling, admission, eviction.

The paper hard-wires three decisions into the service loop — which sample
fills each batch slot (ODS substitution, §5.2), whether a produced form is
worth a cache slot (the all-seen rejection), and when a cached augmented
tensor dies (refcount threshold = number of jobs).  This module extracts
each as a small strategy object so `repro.api.SenecaServer` can mix the
paper's behaviors with baselines (naive sampling, plain LRU) or with
user-registered experiments, CoorDL-style: policy separated from loader
mechanics.

Policies are registered by name; `resolve_policy("sampler", "ods")` is how
string knobs on :class:`repro.api.SenecaConfig` become objects.  Custom
policies register with :func:`register_policy` and are then addressable by
name from configs.

Locking contract (see cache/store.py): ``AdmissionPolicy.wants`` runs under
the service metadata lock, ``AdmissionPolicy.fits`` runs under the *cache*
lock (so the capacity check and the insert are atomic — the seed's
check-then-act race is structurally gone).  The two locks are never held
together in that order, which keeps the service's lock ordering
(metadata -> cache) deadlock-free.
"""
from __future__ import annotations

from typing import Dict, Optional, Protocol, Tuple, runtime_checkable

import numpy as np

from repro.cache.store import CachePartition

__all__ = [
    "SamplerPolicy", "AdmissionPolicy", "EvictionPolicy",
    "OdsSampler", "NaiveSampler",
    "UnseenOnlyAdmission", "CapacityAdmission", "FrequencyAdmission",
    "RefcountEviction", "LruEviction", "CostAwareEviction",
    "register_policy", "resolve_policy", "policy_names",
]


# ----------------------------------------------------------------------
# protocols
@runtime_checkable
class SamplerPolicy(Protocol):
    """Decides the final batch composition for one request."""

    name: str

    def sample(self, backend, job_id: int, requested: np.ndarray,
               evict_threshold: Optional[int]
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Return (batch ids, augmented ids to evict). Runs under the
        service metadata lock."""
        ...


@runtime_checkable
class AdmissionPolicy(Protocol):
    """Two-phase admission: a metadata vote and a capacity vote."""

    name: str

    def wants(self, backend, sample_id: int, form: str) -> bool:
        """Metadata-level decision (under the service lock)."""
        ...

    def fits(self, part: CachePartition, nbytes: int) -> bool:
        """Capacity decision, called under the cache lock immediately
        before the insert."""
        ...


@runtime_checkable
class EvictionPolicy(Protocol):
    """Controls both the per-partition store policy and the ODS step-5
    refcount threshold."""

    name: str

    def partition_policies(self) -> Dict[str, str]:
        """Per-form store policy ("none" | "lru" | "refcount")."""
        ...

    def threshold(self, backend) -> Optional[int]:
        """Refcount at which a served augmented sample is evicted;
        None disables refcount eviction entirely."""
        ...


# ----------------------------------------------------------------------
# sampler implementations
class OdsSampler:
    """The paper's Opportunistic Data Sampling (Fig. 6 steps 1-5)."""

    name = "ods"

    def sample(self, backend, job_id, requested, evict_threshold):
        return backend.sample_batch(job_id, requested,
                                    evict_threshold=evict_threshold)


class NaiveSampler:
    """Serve exactly what the epoch permutation asked for (the paper's
    MDP-only bar); still counts hits/misses for stats."""

    name = "naive"

    def sample(self, backend, job_id, requested, evict_threshold):
        requested = np.asarray(requested)
        backend.count_serve(requested)
        return requested, np.empty(0, np.int64)


# ----------------------------------------------------------------------
# admission implementations
class _CapacityGate:
    def fits(self, part: CachePartition, nbytes: int) -> bool:
        # only "lru" partitions make room inside put(); "none" and
        # "refcount" reject when full, so the entry must fit now — in
        # the DRAM tier or, when the partition has a spill chain, in
        # the disk tier it would overflow to (CachePartition.admits)
        return part.admits(nbytes)


class UnseenOnlyAdmission(_CapacityGate):
    """Reject augmented admissions no registered job could still consume
    this epoch (they would pin a slot until rollover without serving
    anyone — the seed's `admission_value == 0` rule)."""

    name = "unseen-only"

    def wants(self, backend, sample_id, form):
        return form != "augmented" or backend.admission_value(sample_id) > 0


class CapacityAdmission(_CapacityGate):
    """Admit anything that fits (MINIO-style baseline)."""

    name = "capacity"

    def wants(self, backend, sample_id, form):
        return True


class FrequencyAdmission(_CapacityGate):
    """Count-min-sketch doorkeeper (TinyLFU-style): a produced form only
    earns a cache slot once its sample has been produced ``threshold``
    times within the current aging window.  One scan-heavy job streaming
    the dataset once cannot flush the shared cache — its one-touch keys
    never pass the doorkeeper — while any key two jobs touch (or one job
    revisits) is admitted immediately.

    The sketch is ``depth`` rows of ``width`` counters (uint32, a few
    KiB total, zero per-key metadata); over-estimates are possible
    (hash collisions), under-estimates are not, so the filter can only
    err toward admitting — never toward starving a genuinely hot key.
    Counters age by periodic halving every ``window`` observations,
    so long-dead hotness decays instead of accumulating forever.
    ``wants`` runs under the service metadata lock (the standard
    admission contract), which also serializes sketch updates.
    """

    name = "frequency"

    def __init__(self, threshold: int = 2, width: int = 4096,
                 depth: int = 4, window: int = 65536):
        if threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold}")
        self.threshold = int(threshold)
        self.width = int(width)
        self.depth = int(depth)
        self.window = int(window)
        self._table = np.zeros((self.depth, self.width), np.uint32)
        self._seen = 0
        # fixed odd multipliers (splitmix-style) — one hash per row
        self._salts = np.array(
            [0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F,
             0x165667B1, 0xD3A2646D, 0xFD7046C5, 0xB55A4F09][:self.depth],
            np.uint64)

    def _rows(self, sample_id: int) -> np.ndarray:
        h = (np.uint64(sample_id * 2 + 1) * self._salts) >> np.uint64(32)
        return (h % np.uint64(self.width)).astype(np.int64)

    def wants(self, backend, sample_id, form):
        cols = self._rows(int(sample_id))
        rows = np.arange(self.depth)
        self._table[rows, cols] += 1
        estimate = int(self._table[rows, cols].min())
        self._seen += 1
        if self._seen >= self.window:
            # age: halve every counter so stale hotness decays
            self._table >>= 1
            self._seen = 0
        return estimate >= self.threshold


# ----------------------------------------------------------------------
# eviction implementations
class RefcountEviction:
    """Paper §5.2: augmented entries die once every registered job has
    consumed them (threshold tracks the live job count)."""

    name = "refcount"

    def partition_policies(self):
        return {"encoded": "none", "decoded": "none",
                "augmented": "refcount"}

    def threshold(self, backend):
        return backend.n_jobs


class LruEviction:
    """Plain LRU on every tier, no refcount churn (page-cache-like
    baseline)."""

    name = "lru"

    def partition_policies(self):
        return {"encoded": "lru", "decoded": "lru", "augmented": "lru"}

    def threshold(self, backend):
        return None


class CostAwareEviction:
    """Recompute-cost-aware eviction (GDSF, greedy-dual-size-frequency).

    Each DRAM tier under this policy scores entries by
    ``inflation + recompute_cost / nbytes`` and evicts the minimum —
    cheap-to-rebuild bytes (an encoded sample is one storage fetch) make
    way for expensive ones (an augmented tensor embodies fetch + decode +
    augment).  The per-form costs are the telemetry-measured stage
    chains (the paper's t_a / t_da terms): the service pushes fresh
    snapshots through :meth:`refresh` as training warms up, so the
    policy tracks the live pipeline instead of a static size heuristic.
    """

    name = "cost"

    #: pre-telemetry defaults: relative stage weights, not wall seconds
    #: (only the ratio between forms matters before the first refresh)
    DEFAULT_COSTS = {"encoded": 1.0, "decoded": 3.0, "augmented": 4.0}

    def partition_policies(self):
        return {"encoded": "cost", "decoded": "cost", "augmented": "cost"}

    def threshold(self, backend):
        return None

    def refresh(self, cache, snapshot) -> Dict[str, float]:
        """Recompute per-form costs from a telemetry snapshot and push
        them into the cache's "cost" tiers.  A form's cost is the
        latency chain a miss at that form re-pays: fetch for encoded,
        fetch+decode for decoded, fetch+decode+augment for augmented.
        Stages telemetry has not seen yet keep their default weight."""
        lat = snapshot.stage_latency
        # unseen stages read as None from the EWMA map, not 0.0
        fetch = lat.get("fetch_storage") or 0.0
        dec = lat.get("decode") or 0.0
        aug = lat.get("augment") or 0.0
        costs = dict(self.DEFAULT_COSTS)
        if fetch > 0:
            costs["encoded"] = fetch
            if dec > 0:
                costs["decoded"] = fetch + dec
                if aug > 0:
                    costs["augmented"] = fetch + dec + aug
        cache.set_form_costs(costs)
        return costs


# ----------------------------------------------------------------------
# registry
_REGISTRY: Dict[str, Dict[str, type]] = {
    "sampler": {"ods": OdsSampler, "naive": NaiveSampler},
    "admission": {"unseen-only": UnseenOnlyAdmission,
                  "capacity": CapacityAdmission,
                  "frequency": FrequencyAdmission},
    "eviction": {"refcount": RefcountEviction, "lru": LruEviction,
                 "cost": CostAwareEviction},
}

_PROTOCOLS = {"sampler": SamplerPolicy, "admission": AdmissionPolicy,
              "eviction": EvictionPolicy}


def register_policy(kind: str, name: str, factory: type) -> None:
    """Make a policy class addressable by name from SenecaConfig knobs."""
    if kind not in _REGISTRY:
        raise ValueError(f"unknown policy kind {kind!r}; "
                         f"expected one of {sorted(_REGISTRY)}")
    _REGISTRY[kind][name] = factory


def policy_names(kind: str) -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY[kind]))


def resolve_policy(kind: str, spec):
    """Turn a config knob (name string or ready instance) into a policy."""
    if isinstance(spec, str):
        try:
            return _REGISTRY[kind][spec]()
        except KeyError:
            raise ValueError(
                f"unknown {kind} policy {spec!r}; registered: "
                f"{policy_names(kind)}") from None
    if not isinstance(spec, _PROTOCOLS[kind]):
        raise TypeError(f"{spec!r} does not implement the {kind} protocol")
    return spec
