"""Three-form cache with pluggable storage tiers (the Redis analogue,
DESIGN.md §2, plus the SSD spill production systems bolt on).

Byte-accounted partitions for encoded / decoded / augmented samples with
pluggable eviction.  Each partition is a *tier chain*
(:mod:`repro.cache.tiers`): an optional device-resident
:class:`HbmTier` at the head, a :class:`DramTier` (the original dict
store), and an optional :class:`DiskTier` spill area.  Eviction demotes
entries down the chain instead of dropping them (HBM→DRAM on overflow,
DRAM→disk), hits promote back up (disk hits re-enter DRAM; hot DRAM
hits of array payloads earn device residency), and inserts that DRAM
rejects overflow onto disk — so a DRAM-constrained cache degrades to
disk bandwidth instead of storage bandwidth, and a hot augmented set
serves zero-copy from device memory.

Thread-safe: the real data pipeline hits this store from fetch worker
threads while the trainer consumes batches.  All chain behavior runs
under the single cache lock; tiers themselves are lock-free.
Spill-tier file *writes* are write-behind: ``DiskTier.put`` stages the
payload under the lock, and each mutating public method drains the
stage via :meth:`DiskTier.flush_staged` — write + fsync running with
the lock released — before returning, so a slow SSD no longer stalls
every concurrent lookup (the PR 5 known limitation).  Codec *reads* on
disk hits still run under the lock.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.cache.tiers import (MISS, DiskTier, DramTier, HbmTier,
                               PartitionStats, Tier)

__all__ = ["FORMS", "PartitionStats", "CachePartition", "TieredCache",
           "Tier", "DramTier", "DiskTier", "HbmTier"]

FORMS = ("encoded", "decoded", "augmented")

#: residency levels reported by :meth:`TieredCache.residency_array`
RESIDENCY_NONE, RESIDENCY_DISK, RESIDENCY_DRAM = 0, 1, 2
RESIDENCY_HBM = 3


class CachePartition:
    """One form's partition: an optional device (HBM) tier, a DRAM tier
    and an optional disk spill tier, chained with byte accounting + LRU
    order per tier.

    The public surface (and the DRAM-only behavior) is identical to the
    pre-chain ``CachePartition``; ``stats``/``_data``/``_sizes`` keep
    addressing the DRAM tier so existing accounting assertions hold
    unchanged.  Keys evicted *out of the chain entirely* (spill
    overflow, promotion backfill) are recorded in ``pending_evicted``
    for the service to reconcile ODS metadata with.

    HBM chain rules: array payloads whose insert the HBM tier admits go
    device-resident immediately (``device_put``); others land in DRAM,
    and a DRAM entry that takes ``HBM_PROMOTE_HITS`` lookup hits is
    promoted up.  HBM overflow/resize demotes down into DRAM (host
    copies), cascading into the spill tier like any DRAM eviction.
    """

    #: DRAM lookup hits (of an HBM-eligible payload) before promotion
    HBM_PROMOTE_HITS = 2

    def __init__(self, capacity_bytes: int, evict_policy: str = "none",
                 spill: Optional[DiskTier] = None,
                 hbm: Optional[HbmTier] = None):
        self.dram = DramTier(capacity_bytes, evict_policy)
        self.spill = spill
        self.hbm = hbm
        # keys no longer resident anywhere in the chain, awaiting a
        # metadata patch (drained via TieredCache.take_evicted)
        self.pending_evicted: List[int] = []
        # chain traffic counters (how the spill is actually behaving)
        self.demotions = 0
        self.promotions = 0
        # device-tier traffic + DRAM hit-heat driving promotion
        self.hbm_promotions = 0
        self.hbm_demotions = 0
        self._heat: Dict[int, int] = {}

    # -- compat surface over the DRAM tier -----------------------------
    @property
    def capacity(self) -> int:
        return self.dram.capacity

    @capacity.setter
    def capacity(self, value: int) -> None:
        self.dram.capacity = int(value)

    @property
    def policy(self) -> str:
        return self.dram.policy

    @property
    def stats(self) -> PartitionStats:
        return self.dram.stats

    @property
    def _data(self):
        return self.dram._data

    @property
    def _sizes(self):
        return self.dram._sizes

    @property
    def free_bytes(self) -> int:
        return self.dram.free_bytes

    @property
    def total_capacity(self) -> int:
        return (self.dram.capacity
                + (self.spill.capacity if self.spill else 0)
                + (self.hbm.capacity if self.hbm else 0))

    # -- chain-aggregate stats -----------------------------------------
    @property
    def total_hits(self) -> int:
        return (self.dram.stats.hits
                + (self.spill.stats.hits if self.spill else 0)
                + (self.hbm.stats.hits if self.hbm else 0))

    @property
    def total_misses(self) -> int:
        return self.dram.stats.misses + (self.spill.stats.misses
                                         if self.spill else 0)

    # ------------------------------------------------------------------
    def __contains__(self, key: int) -> bool:
        return (key in self.dram
                or (self.spill is not None and key in self.spill)
                or (self.hbm is not None and key in self.hbm))

    def __len__(self) -> int:
        return (len(self.dram) + (len(self.spill) if self.spill else 0)
                + (len(self.hbm) if self.hbm else 0))

    def keys(self) -> List[int]:
        ks = self.dram.keys()
        if self.spill is not None:
            ks += self.spill.keys()
        if self.hbm is not None:
            ks += self.hbm.keys()
        return ks

    def tier_of(self, key: int) -> Optional[str]:
        if self.hbm is not None and key in self.hbm:
            return "hbm"
        if key in self.dram:
            return "dram"
        if self.spill is not None and key in self.spill:
            return "disk"
        return None

    # ------------------------------------------------------------------
    def get(self, key: int, default: Any = None) -> Any:
        return self.get_tiered(key, default)[0]

    def get_tiered(self, key: int, default: Any = None
                   ) -> Tuple[Any, Optional[str]]:
        """Chain lookup counting exactly one hit or miss; disk hits
        promote back to DRAM when it has (or can make) room, hot DRAM
        hits promote up to the device tier.  Returns ``(value, tier)``
        with tier in ("hbm", "dram", "disk", None) — an "hbm" hit
        serves the device-resident ``jax.Array`` zero-copy."""
        if self.hbm is not None:
            v = self.hbm.peek(key, MISS)
            if v is not MISS:
                return self.hbm.get(key, default), "hbm"
        v = self.dram.peek(key, MISS)
        if v is not MISS:
            value = self.dram.get(key, default)
            self._maybe_promote_hbm(key, value)
            return value, "dram"
        if self.spill is not None and key in self.spill:
            v = self.spill.get(key, MISS)   # counts the disk hit
            if v is not MISS:
                self._promote(key, v)
                return v, "disk"
            return default, None            # file vanished: disk miss
        self.dram.stats.misses += 1
        return default, None

    def peek(self, key: int, default: Any = None) -> Any:
        """Stats-neutral read: no hit/miss counting, no LRU promotion.
        For controller/refill scans that inspect residency without being
        part of the serving path."""
        if self.hbm is not None:
            v = self.hbm.peek(key, MISS)
            if v is not MISS:
                return v
        v = self.dram.peek(key, MISS)
        if v is not MISS:
            return v
        if self.spill is not None:
            return self.spill.peek(key, default)
        return default

    def _promote(self, key: int, value: Any) -> None:
        """Move a disk hit up to DRAM (LRU partitions make room by
        demoting their coldest entries back down; no-evict partitions
        promote only into free space — otherwise the entry stays on
        disk and keeps serving from there)."""
        nbytes = self.spill.size_of(key)
        if nbytes is None or not self.dram.admits(nbytes):
            return
        demoted = self.dram.put(key, value, nbytes)
        if key in self.dram:
            self.spill.discard(key)
            self.promotions += 1
        self._demote(demoted)

    def _demote(self, entries) -> None:
        """Push DRAM-evicted entries down into the spill tier; entries
        the spill cannot hold (and entries the spill evicts to make
        room) leave the chain and are queued for metadata patching.
        Without a spill tier nothing queues — chain-leavers are exactly
        the caller-visible eviction lists the pre-chain code returned,
        so no reconcile pass exists (or is needed) to drain them."""
        for k, _v, _nb in entries:
            # every entry here left DRAM: its promotion heat is stale
            # (a later re-entry must re-earn device residency) and the
            # map must not grow toward n_total over long runs
            self._heat.pop(k, None)
        if self.spill is None:
            return
        for k, v, nb in entries:
            placed = False
            if self.spill.admits(nb):
                for ek, _ev, _enb in self.spill.put(k, v, nb):
                    self.pending_evicted.append(ek)
                placed = k in self.spill
                if placed:
                    self.demotions += 1
            if not placed:
                self.pending_evicted.append(k)

    def _maybe_promote_hbm(self, key: int, value: Any) -> None:
        """Count a DRAM hit toward device promotion; on the
        ``HBM_PROMOTE_HITS``-th hit of an HBM-eligible payload, move it
        up (device_put) and cascade any HBM evictions back down."""
        if self.hbm is None or not HbmTier.wants_value(value):
            return
        heat = self._heat.get(key, 0) + 1
        if heat < self.HBM_PROMOTE_HITS:
            self._heat[key] = heat
            return
        self._heat.pop(key, None)
        entry = self.dram.pop_entry(key)
        if entry is None:
            return
        _v, nbytes = entry
        if not self.hbm.admits(nbytes):
            # oversized for the device tier: put it straight back (the
            # slot it just vacated is still free, so this cannot evict)
            self.dram.put(key, value, nbytes)
            return
        demoted = self.hbm.put(key, value, nbytes)
        if key in self.hbm:
            self.hbm_promotions += 1
        self._demote_hbm(demoted)

    def _demote_hbm(self, entries) -> None:
        """Push HBM-evicted entries down into DRAM as host copies,
        cascading DRAM overflow into the spill tier; entries nothing
        below can hold leave the chain (queued for metadata patching —
        unlike :meth:`_demote`, chain-leavers queue even without a
        spill tier, because HBM demotion happens during *lookups* where
        the caller sees no eviction list)."""
        for k, v, nb in entries:
            host = np.asarray(v)
            placed = False
            if self.dram.admits(nb):
                dram_evicted = self.dram.put(k, host, nb)
                placed = k in self.dram
                if placed:
                    self.hbm_demotions += 1
                if self.spill is None:
                    for ek, _ev, _enb in dram_evicted:
                        self._heat.pop(ek, None)
                        self.pending_evicted.append(ek)
                else:
                    self._demote(dram_evicted)
            if not placed:
                if self.spill is not None and self.spill.admits(nb):
                    for ek, _ev, _enb in self.spill.put(k, host, nb):
                        self.pending_evicted.append(ek)
                    placed = k in self.spill
                    if placed:
                        self.hbm_demotions += 1
                if not placed:
                    self.pending_evicted.append(k)

    # ------------------------------------------------------------------
    def admits(self, nbytes: int) -> bool:
        """Could an insert of ``nbytes`` land anywhere in the chain?"""
        if self.hbm is not None and self.hbm.admits(nbytes):
            return True
        if self.dram.admits(nbytes):
            return True
        return self.spill is not None and self.spill.admits(nbytes)

    def put(self, key: int, value: Any, nbytes: int) -> List[int]:
        """Insert; returns the keys evicted *out of the chain* (never
        evicts under 'none' — the insert overflows to the spill tier
        when one exists, or is rejected, MINIO-style).  Re-inserting an
        existing key replaces it.  Array payloads the device tier
        admits go HBM-resident immediately; HBM evictions cascade down
        the chain like any demotion."""
        if (self.hbm is not None and HbmTier.wants_value(value)
                and self.hbm.admits(nbytes)):
            demoted = self.hbm.put(key, value, nbytes)
            evicted: List[int] = []
            if key in self.hbm:
                # single-residence invariant across all three tiers
                self.dram.pop_entry(key)
                if self.spill is not None:
                    self.spill.discard(key)
                self._heat.pop(key, None)
                self._demote_hbm(demoted)
                evicted.extend(k for k, _v, _nb in demoted
                               if k not in self)
                return evicted
            # no-evict HBM rejected after all: fall through to DRAM
        demoted = self.dram.put(key, value, nbytes)
        evicted = []
        if key in self.dram:
            # single-residence invariant: a fresh DRAM copy supersedes
            # any stale spill (or device) copy from earlier demotions
            if self.spill is not None:
                self.spill.discard(key)
            if self.hbm is not None:
                self.hbm.remove(key)
        elif self.spill is not None:
            # DRAM rejected (no-evict policy full / oversized): spill
            # admission keeps the entry cached at disk speed
            for ek, _ev, _enb in self.spill.put(key, value, nbytes):
                self.pending_evicted.append(ek)
                evicted.append(ek)
        self._demote(demoted)
        evicted.extend(k for k, _v, _nb in demoted
                       if k not in self)
        return evicted

    def set_capacity(self, capacity_bytes: int) -> List[int]:
        """Resize the DRAM level live; returns the keys evicted out of
        the chain.  Shrinking demotes through the partition's own policy
        order — LRU order for "lru", insertion (FIFO) order for
        "none"/"refcount" — into the spill tier when one exists, rather
        than dropping.  Byte accounting stays exact per tier (asserted
        by tests/test_cache_properties.py)."""
        demoted = self.dram.set_capacity(capacity_bytes)
        self._demote(demoted)
        return [k for k, _v, _nb in demoted if k not in self]

    def set_spill_capacity(self, capacity_bytes: int) -> List[int]:
        """Resize the disk level live; spill shrink evictions are
        terminal."""
        if self.spill is None:
            return []
        evicted = [k for k, _v, _nb in
                   self.spill.set_capacity(capacity_bytes)]
        self.pending_evicted.extend(evicted)
        return evicted

    def set_hbm_capacity(self, capacity_bytes: int) -> List[int]:
        """Resize the device level live; shrink demotions cascade down
        the chain (host copies into DRAM, overflowing to disk) and the
        keys evicted out of the chain entirely are returned."""
        if self.hbm is None:
            return []
        demoted = self.hbm.set_capacity(capacity_bytes)
        self._demote_hbm(demoted)
        return [k for k, _v, _nb in demoted if k not in self]

    def remove(self, key: int) -> bool:
        """Drop ``key`` from every tier (refcount eviction consumes the
        sample entirely — a spilled or device copy must not resurrect
        it)."""
        dropped = self.dram.remove(key)
        if self.spill is not None and self.spill.remove(key):
            dropped = True
        if self.hbm is not None and self.hbm.remove(key):
            dropped = True
        self._heat.pop(key, None)
        return dropped

    def take_pending_evicted(self) -> List[int]:
        out = self.pending_evicted
        self.pending_evicted = []
        return out


class TieredCache:
    """The Seneca cache: three partitions sized by an MDP split, each an
    optional HBM→DRAM→disk tier chain sized by the form×tier MDP."""

    def __init__(self, capacity_bytes: int,
                 split: Tuple[float, float, float],
                 evict_policies: Optional[Dict[str, str]] = None,
                 spill_bytes: int = 0,
                 spill_dir: Optional[str] = None,
                 spill_split: Optional[Tuple[float, float, float]] = None,
                 hbm_bytes: int = 0,
                 hbm_split: Optional[Tuple[float, float, float]] = None):
        x_e, x_d, x_a = split
        assert abs(x_e + x_d + x_a - 1.0) < 1e-6, split
        pol = evict_policies or {"encoded": "none", "decoded": "none",
                                 "augmented": "refcount"}
        self.capacity = capacity_bytes
        self.split = split
        self.spill_bytes = int(spill_bytes) if spill_dir else 0
        self.spill_dir = spill_dir if self.spill_bytes > 0 else None
        if self.spill_dir is not None:
            self.spill_split = tuple(spill_split) if spill_split \
                else tuple(split)
            y_e, y_d, y_a = self.spill_split
            assert abs(y_e + y_d + y_a - 1.0) < 1e-6, self.spill_split
            spills = {form: DiskTier(int(y * self.spill_bytes),
                                     self.spill_dir, form)
                      for form, y in zip(FORMS, (y_e, y_d, y_a))}
        else:
            self.spill_split = None
            spills = {form: None for form in FORMS}
        self.hbm_bytes = int(hbm_bytes)
        if self.hbm_bytes > 0:
            self.hbm_split = tuple(hbm_split) if hbm_split \
                else tuple(split)
            z_e, z_d, z_a = self.hbm_split
            assert abs(z_e + z_d + z_a - 1.0) < 1e-6, self.hbm_split
            # LRU on device: HBM is small and hot — coldest array falls
            # back to DRAM rather than blocking new promotions
            hbms = {form: HbmTier(int(z * self.hbm_bytes), "lru")
                    for form, z in zip(FORMS, (z_e, z_d, z_a))}
        else:
            self.hbm_split = None
            hbms = {form: None for form in FORMS}
        self.parts: Dict[str, CachePartition] = {
            "encoded": CachePartition(int(x_e * capacity_bytes),
                                      pol["encoded"], spills["encoded"],
                                      hbms["encoded"]),
            "decoded": CachePartition(int(x_d * capacity_bytes),
                                      pol["decoded"], spills["decoded"],
                                      hbms["decoded"]),
            "augmented": CachePartition(int(x_a * capacity_bytes),
                                        pol["augmented"],
                                        spills["augmented"],
                                        hbms["augmented"]),
        }
        self.lock = threading.Lock()
        self._closed = False
        # misses counted at lookup granularity: a key absent from every
        # partition is ONE miss, not zero (the partitions are only probed
        # via __contains__) and not three
        self.lookup_misses = 0
        # bumped on every mutation that can change residency (insert,
        # evict, resize, disk-hit promotion) so the service can skip
        # rebuilding the O(N) residency array when nothing moved
        self.version = 0

    @property
    def has_spill(self) -> bool:
        return self.spill_dir is not None

    @property
    def has_hbm(self) -> bool:
        return self.hbm_bytes > 0

    def _flush_spill(self) -> None:
        """Drain staged write-behind spill payloads, releasing the cache
        lock around each file write (:meth:`DiskTier.flush_staged`).
        Called *after* the lock is dropped by every mutating public
        method, so op boundaries observe index == files-on-disk."""
        if not self.has_spill:
            return
        for part in self.parts.values():
            part.spill.flush_staged(self.lock)

    def lookup(self, key: int) -> Tuple[Optional[str], Any]:
        """Most-processed form first (augmented > decoded > encoded)."""
        form, value, _tier = self.lookup_tiered(key)
        return form, value

    def lookup_tiered(self, key: int
                      ) -> Tuple[Optional[str], Any, Optional[str]]:
        """Like :meth:`lookup` but also names the tier that answered
        ("hbm" | "dram" | "disk" | None) so telemetry can track
        per-tier serve bandwidths."""
        try:
            with self.lock:
                for form in ("augmented", "decoded", "encoded"):
                    part = self.parts[form]
                    if key in part:
                        promos = part.promotions + part.hbm_promotions
                        value, tier = part.get_tiered(key, MISS)
                        if value is not MISS:
                            # only an actual promotion changes residency;
                            # a disk hit that stays on disk must not
                            # defeat the version-gated residency rebuild
                            if (part.promotions
                                    + part.hbm_promotions != promos):
                                self.version += 1
                            return form, value, tier
                self.lookup_misses += 1
                return None, None, None
        finally:
            # promotions can cascade demotions into the spill stage
            self._flush_spill()

    def insert(self, key: int, form: str, value: Any, nbytes: int) -> bool:
        """Insert; True when the key is resident afterwards."""
        with self.lock:
            self.version += 1
            self.parts[form].put(key, value, nbytes)
            resident = key in self.parts[form]
        self._flush_spill()
        return resident

    def insert_gated(self, key: int, form: str, value: Any, nbytes: int,
                     policy) -> bool:
        """Insert with the admission policy's capacity vote evaluated under
        the cache lock, atomically with the put — concurrent workers cannot
        both pass a stale free-bytes check."""
        with self.lock:
            part = self.parts[form]
            if not policy.fits(part, nbytes):
                return False
            self.version += 1
            part.put(key, value, nbytes)
            resident = key in part
        self._flush_spill()
        return resident

    def insert_batch_gated(self, form: str, entries, policy) -> List[bool]:
        """Batch-granular admission: ``entries`` is a sequence of
        ``(key, value, nbytes)``; the capacity vote + insert for the whole
        batch run under ONE cache-lock acquisition (the stage-parallel
        pipeline's per-batch admission — vs one acquisition per sample).

        Per-entry semantics are identical to :meth:`insert_gated`: each
        entry is voted with the partition state the previous entries
        left behind — a rejected entry does NOT reject the rest, so a
        later, smaller entry may still fit (same results as N looped
        ``insert_gated`` calls).  Returns one bool per entry.
        """
        out: List[bool] = []
        with self.lock:
            part = self.parts[form]
            for key, value, nbytes in entries:
                if not policy.fits(part, nbytes):
                    out.append(False)
                    continue
                self.version += 1
                part.put(key, value, nbytes)
                out.append(key in part)
        self._flush_spill()
        return out

    def evict(self, key: int, form: str) -> bool:
        with self.lock:
            self.version += 1
            return self.parts[form].remove(key)

    def peek(self, key: int) -> Tuple[Optional[str], Any]:
        """Stats-neutral lookup (same tier order), for controller/refill
        scans — ``lookup`` would inflate miss counts.  Loads spilled
        payloads from disk; callers that only need the *form* should use
        :meth:`form_of` (containment-only, no IO under the lock)."""
        with self.lock:
            for form in ("augmented", "decoded", "encoded"):
                part = self.parts[form]
                if key in part:
                    return form, part.peek(key)
            return None, None

    def form_of(self, key: int) -> Optional[str]:
        """The form a lookup would serve (most-processed resident), by
        containment only — no payload read, no stats, no promotion."""
        with self.lock:
            for form in ("augmented", "decoded", "encoded"):
                if key in self.parts[form]:
                    return form
            return None

    # -- containment / capacity queries --------------------------------
    # The service layer's window onto the cache.  These (not `parts` /
    # `lock` pokes) are the contract a drop-in cache implementation —
    # e.g. the sharded service client — must satisfy.

    def contains(self, form: str, key: int) -> bool:
        """Is ``key`` resident (any tier) in ``form``'s partition?"""
        with self.lock:
            return key in self.parts[form]

    def contains_many(self, form: str, keys) -> List[bool]:
        """Batch :meth:`contains` under one lock acquisition."""
        with self.lock:
            part = self.parts[form]
            return [k in part for k in keys]

    def serving_forms(self, keys) -> List[Optional[str]]:
        """Batch :meth:`form_of` under one lock acquisition: per key,
        the most-processed resident form (or None)."""
        out: List[Optional[str]] = []
        with self.lock:
            for k in keys:
                for form in ("augmented", "decoded", "encoded"):
                    if k in self.parts[form]:
                        out.append(form)
                        break
                else:
                    out.append(None)
        return out

    def total_capacity(self, form: str) -> int:
        """DRAM + spill capacity of ``form``'s tier chain (bytes)."""
        return self.parts[form].total_capacity

    def chain_free_bytes(self, form: str) -> int:
        """Free bytes across ``form``'s whole tier chain."""
        with self.lock:
            part = self.parts[form]
            free = part.free_bytes
            if part.spill is not None:
                free += part.spill.free_bytes
            if part.hbm is not None:
                free += part.hbm.free_bytes
            return free

    def set_form_costs(self, costs: Dict[str, float]) -> None:
        """Push telemetry-measured recompute costs (seconds per entry)
        into each form's "cost"-policy DRAM tier; no-op for other
        policies (the GDSF eviction satellite's feedback path)."""
        with self.lock:
            for form, cost in costs.items():
                dram = self.parts[form].dram
                if dram.policy == "cost" and cost and cost > 0:
                    dram.set_cost(float(cost))

    def take_evicted(self) -> List[int]:
        """Drain the keys the chains evicted as a side effect (spill
        overflow, promotion backfill) since the last drain — the service
        patches ODS metadata with them (reconcile_evictions)."""
        with self.lock:
            out: List[int] = []
            for part in self.parts.values():
                out.extend(part.take_pending_evicted())
            return out

    def has_pending_evicted(self) -> bool:
        with self.lock:
            return any(part.pending_evicted
                       for part in self.parts.values())

    def resize(self, split: Tuple[float, float, float],
               spill_split: Optional[Tuple[float, float, float]] = None,
               hbm_split: Optional[Tuple[float, float, float]] = None
               ) -> Dict[str, List[int]]:
        """Re-partition the same total capacity live under the cache lock.

        Shrinking partitions evict (policy order) down to their new
        capacity; growing ones just gain headroom.  Shrinks are applied
        before grows so the instantaneous sum of partition capacities
        never exceeds the total.  With a spill tier, DRAM shrink
        evictions demote to disk, and ``spill_split`` (defaulting to
        ``split``) resizes the disk level the same way — disk grows
        first so demotion traffic lands in the enlarged tiers, disk
        shrinks last.  With a device tier, ``hbm_split`` resizes the
        HBM level: HBM shrinks before the DRAM pass (demotions land in
        the still-sized DRAM/disk tiers) and grows after it.  Returns
        ``{form: [keys evicted out of the chain]}`` so the caller can
        demote/patch ODS metadata.
        """
        x_e, x_d, x_a = split
        if abs(x_e + x_d + x_a - 1.0) >= 1e-6:
            raise ValueError(f"split must sum to 1: {split}")
        targets = {"encoded": int(x_e * self.capacity),
                   "decoded": int(x_d * self.capacity),
                   "augmented": int(x_a * self.capacity)}
        evicted: Dict[str, List[int]] = {}

        def add(form: str, keys: List[int]) -> None:
            if keys:
                evicted.setdefault(form, []).extend(keys)

        with self.lock:
            disk_targets = None
            if self.has_spill:
                ys = tuple(spill_split) if spill_split is not None \
                    else (float(x_e), float(x_d), float(x_a))
                if abs(sum(ys) - 1.0) >= 1e-6:
                    raise ValueError(
                        f"spill_split must sum to 1: {ys}")
                disk_targets = {f: int(y * self.spill_bytes)
                                for f, y in zip(FORMS, ys)}
                # disk grows first: DRAM-shrink demotions flow into the
                # enlarged spill tiers instead of being dropped
                for form in FORMS:
                    part = self.parts[form]
                    if disk_targets[form] >= part.spill.capacity:
                        add(form, part.set_spill_capacity(
                            disk_targets[form]))
                self.spill_split = tuple(float(y) for y in ys)
            hbm_targets = None
            if self.has_hbm:
                zs = tuple(hbm_split) if hbm_split is not None \
                    else (float(x_e), float(x_d), float(x_a))
                if abs(sum(zs) - 1.0) >= 1e-6:
                    raise ValueError(
                        f"hbm_split must sum to 1: {zs}")
                hbm_targets = {f: int(z * self.hbm_bytes)
                               for f, z in zip(FORMS, zs)}
                # HBM shrinks before the DRAM pass so device demotions
                # land in tiers that still have their old headroom
                for form in FORMS:
                    part = self.parts[form]
                    if hbm_targets[form] < part.hbm.capacity:
                        add(form, part.set_hbm_capacity(
                            hbm_targets[form]))
                self.hbm_split = tuple(float(z) for z in zs)
            order = sorted(FORMS,
                           key=lambda f: targets[f] - self.parts[f].capacity)
            for form in order:            # shrinks first, then grows
                add(form, self.parts[form].set_capacity(targets[form]))
            if hbm_targets is not None:   # HBM grows after the DRAM pass
                for form in FORMS:
                    part = self.parts[form]
                    if hbm_targets[form] >= part.hbm.capacity:
                        add(form, part.set_hbm_capacity(
                            hbm_targets[form]))
            if disk_targets is not None:  # disk shrinks last
                for form in FORMS:
                    part = self.parts[form]
                    if disk_targets[form] < part.spill.capacity:
                        add(form, part.set_spill_capacity(
                            disk_targets[form]))
            self.split = (float(x_e), float(x_d), float(x_a))
            self.version += 1
        self._flush_spill()
        return evicted

    def status_array(self, n: int) -> np.ndarray:
        """uint8[N] of ODS status codes (0 storage / 1 enc / 2 dec / 3
        aug); disk-resident entries keep their form's code — residency
        *level* is :meth:`residency_array`'s job."""
        out = np.zeros(n, np.uint8)
        with self.lock:
            for code, form in ((1, "encoded"), (2, "decoded"),
                               (3, "augmented")):
                ks = self.parts[form].keys()
                if ks:
                    out[np.asarray(ks, int)] = code
        return out

    def residency_array(self, n: int) -> np.ndarray:
        """uint8[N] residency levels: 0 = storage only, 1 = disk,
        2 = DRAM, 3 = HBM — of the form a lookup would actually serve
        (the most-processed resident form), not the best tier over all
        forms: a sample whose augmented copy spilled to disk serves at
        disk latency even if its encoded copy sits in DRAM.  Feeds the
        ODS substitution preference (device hits beat DRAM hits beat
        disk hits beat storage misses)."""
        out = np.zeros(n, np.uint8)
        with self.lock:
            # lowest serving priority first; higher-priority forms
            # overwrite, so each sample ends at its serving form's tier
            # (within a form the tiers are disjoint — single residence)
            for form in ("encoded", "decoded", "augmented"):
                part = self.parts[form]
                if part.spill is not None:
                    ks = part.spill.keys()
                    if ks:
                        out[np.asarray(ks, int)] = RESIDENCY_DISK
                ks = part.dram.keys()
                if ks:
                    out[np.asarray(ks, int)] = RESIDENCY_DRAM
                if part.hbm is not None:
                    ks = part.hbm.keys()
                    if ks:
                        out[np.asarray(ks, int)] = RESIDENCY_HBM
        return out

    def hit_rate(self) -> float:
        h = sum(p.total_hits for p in self.parts.values())
        m = sum(p.total_misses
                for p in self.parts.values()) + self.lookup_misses
        return h / (h + m) if h + m else 0.0

    def bytes_used(self) -> int:
        return sum(p.stats.bytes_used for p in self.parts.values())

    def disk_bytes_used(self) -> int:
        return sum(p.spill.stats.bytes_used for p in self.parts.values()
                   if p.spill is not None)

    def hbm_bytes_used(self) -> int:
        return sum(p.hbm.stats.bytes_used for p in self.parts.values()
                   if p.hbm is not None)

    def hbm_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-form device-tier traffic (JSON-friendly; empty without an
        HBM tier)."""
        if not self.has_hbm:
            return {}
        with self.lock:
            return {form: {
                "hbm_bytes_used": part.hbm.stats.bytes_used,
                "hbm_capacity": part.hbm.capacity,
                "hbm_entries": len(part.hbm),
                "hbm_hits": part.hbm.stats.hits,
                "hbm_promotions": part.hbm_promotions,
                "hbm_demotions": part.hbm_demotions,
            } for form, part in self.parts.items()}

    def spill_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-form chain traffic (JSON-friendly; empty without spill)."""
        if not self.has_spill:
            return {}
        with self.lock:
            return {form: {
                "disk_bytes_used": part.spill.stats.bytes_used,
                "disk_capacity": part.spill.capacity,
                "disk_entries": len(part.spill),
                "disk_hits": part.spill.stats.hits,
                "demotions": part.demotions,
                "promotions": part.promotions,
                "io_errors": part.spill.io_errors,
            } for form, part in self.parts.items()}

    def close(self) -> None:
        """Tear down the spill area: every entry file is unlinked and
        the per-form directories removed (the no-leaked-files contract
        asserted by the tiered-cache benchmark and CI).

        Idempotent and exception-safe: shard teardown reaches here from
        several paths (transport close, failed server construction,
        ``with`` exits), so a second call is a no-op and an OSError
        from one form's cleanup doesn't abort the others."""
        with self.lock:
            if self._closed:
                return
            failed = False
            for part in self.parts.values():
                if part.spill is not None:
                    try:
                        part.spill.clear()
                    except OSError:
                        part.spill.io_errors += 1
                        failed = True
            # only latch closed once every spill dir actually emptied,
            # so a transient IO failure can be retried by a later close
            self._closed = not failed
