"""Three-form cache with pluggable storage tiers (the Redis analogue,
DESIGN.md §2, plus the SSD spill production systems bolt on).

Byte-accounted partitions for encoded / decoded / augmented samples with
pluggable eviction.  Each partition is a *tier chain*
(:mod:`repro.cache.tiers`): an optional device-resident
:class:`HbmTier` at the head, a :class:`DramTier` (the original dict
store), and an optional :class:`DiskTier` spill area.  Eviction demotes
entries down the chain instead of dropping them (HBM→DRAM on overflow,
DRAM→disk), hits promote back up (disk hits re-enter DRAM; hot DRAM
hits of array payloads earn device residency), and inserts that DRAM
rejects overflow onto disk — so a DRAM-constrained cache degrades to
disk bandwidth instead of storage bandwidth, and a hot augmented set
serves zero-copy from device memory.

Thread-safe: the real data pipeline hits this store from fetch worker
threads while the trainer consumes batches.  All chain behavior runs
under the cache's locks; tiers themselves are lock-free.  With the
default ``n_stripes=1`` every operation serializes on one lock exactly
as the engine always did.  ``n_stripes>1`` hash-stripes the key space:
each stripe owns its own per-form partition chains, byte ledgers and
lock, so per-key hot-path operations (lookup / insert / contains /
evict) on different stripes no longer contend.  Whole-cache operations
(resize, close, ``cache.lock``) take every stripe lock in ascending
index order — one fixed global order, so they can never deadlock
against each other — and aggregate views (``stats`` / ``status_array``
/ ``hit_rate``) sum the stripe-local ledgers on read.
Spill-tier file *writes* are write-behind: ``DiskTier.put`` stages the
payload under the lock, and each mutating public method drains the
stage via :meth:`DiskTier.flush_staged` — write + fsync running with
the stripe's lock released — before returning, so a slow SSD no longer
stalls every concurrent lookup (the PR 5 known limitation).  Codec
*reads* on disk hits still run under the lock.
"""
from __future__ import annotations

import os
import threading
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.cache.tiers import (MISS, DiskTier, DramTier, HbmTier,
                               PartitionStats, Tier)

__all__ = ["FORMS", "PartitionStats", "CachePartition", "TieredCache",
           "Tier", "DramTier", "DiskTier", "HbmTier"]

FORMS = ("encoded", "decoded", "augmented")

#: residency levels reported by :meth:`TieredCache.residency_array`
RESIDENCY_NONE, RESIDENCY_DISK, RESIDENCY_DRAM = 0, 1, 2
RESIDENCY_HBM = 3


class CachePartition:
    """One form's partition: an optional device (HBM) tier, a DRAM tier
    and an optional disk spill tier, chained with byte accounting + LRU
    order per tier.

    The public surface (and the DRAM-only behavior) is identical to the
    pre-chain ``CachePartition``; ``stats``/``_data``/``_sizes`` keep
    addressing the DRAM tier so existing accounting assertions hold
    unchanged.  Keys evicted *out of the chain entirely* (spill
    overflow, promotion backfill) are recorded in ``pending_evicted``
    for the service to reconcile ODS metadata with.

    HBM chain rules: array payloads whose insert the HBM tier admits go
    device-resident immediately (``device_put``); others land in DRAM,
    and a DRAM entry that takes ``HBM_PROMOTE_HITS`` lookup hits is
    promoted up.  HBM overflow/resize demotes down into DRAM (host
    copies), cascading into the spill tier like any DRAM eviction.
    """

    #: DRAM lookup hits (of an HBM-eligible payload) before promotion
    HBM_PROMOTE_HITS = 2

    def __init__(self, capacity_bytes: int, evict_policy: str = "none",
                 spill: Optional[DiskTier] = None,
                 hbm: Optional[HbmTier] = None):
        self.dram = DramTier(capacity_bytes, evict_policy)
        self.spill = spill
        self.hbm = hbm
        # keys no longer resident anywhere in the chain, awaiting a
        # metadata patch (drained via TieredCache.take_evicted)
        self.pending_evicted: List[int] = []
        # chain traffic counters (how the spill is actually behaving)
        self.demotions = 0
        self.promotions = 0
        # device-tier traffic + DRAM hit-heat driving promotion
        self.hbm_promotions = 0
        self.hbm_demotions = 0
        self._heat: Dict[int, int] = {}

    # -- compat surface over the DRAM tier -----------------------------
    @property
    def capacity(self) -> int:
        return self.dram.capacity

    @capacity.setter
    def capacity(self, value: int) -> None:
        self.dram.capacity = int(value)

    @property
    def policy(self) -> str:
        return self.dram.policy

    @property
    def stats(self) -> PartitionStats:
        return self.dram.stats

    @property
    def _data(self):
        return self.dram._data

    @property
    def _sizes(self):
        return self.dram._sizes

    @property
    def free_bytes(self) -> int:
        return self.dram.free_bytes

    @property
    def total_capacity(self) -> int:
        return (self.dram.capacity
                + (self.spill.capacity if self.spill else 0)
                + (self.hbm.capacity if self.hbm else 0))

    # -- chain-aggregate stats -----------------------------------------
    @property
    def total_hits(self) -> int:
        return (self.dram.stats.hits
                + (self.spill.stats.hits if self.spill else 0)
                + (self.hbm.stats.hits if self.hbm else 0))

    @property
    def total_misses(self) -> int:
        return self.dram.stats.misses + (self.spill.stats.misses
                                         if self.spill else 0)

    # ------------------------------------------------------------------
    def __contains__(self, key: int) -> bool:
        return (key in self.dram
                or (self.spill is not None and key in self.spill)
                or (self.hbm is not None and key in self.hbm))

    def __len__(self) -> int:
        return (len(self.dram) + (len(self.spill) if self.spill else 0)
                + (len(self.hbm) if self.hbm else 0))

    def keys(self) -> List[int]:
        ks = self.dram.keys()
        if self.spill is not None:
            ks += self.spill.keys()
        if self.hbm is not None:
            ks += self.hbm.keys()
        return ks

    def tier_of(self, key: int) -> Optional[str]:
        if self.hbm is not None and key in self.hbm:
            return "hbm"
        if key in self.dram:
            return "dram"
        if self.spill is not None and key in self.spill:
            return "disk"
        return None

    # ------------------------------------------------------------------
    def get(self, key: int, default: Any = None) -> Any:
        return self.get_tiered(key, default)[0]

    def get_tiered(self, key: int, default: Any = None
                   ) -> Tuple[Any, Optional[str]]:
        """Chain lookup counting exactly one hit or miss; disk hits
        promote back to DRAM when it has (or can make) room, hot DRAM
        hits promote up to the device tier.  Returns ``(value, tier)``
        with tier in ("hbm", "dram", "disk", None) — an "hbm" hit
        serves the device-resident ``jax.Array`` zero-copy."""
        if self.hbm is not None:
            v = self.hbm.peek(key, MISS)
            if v is not MISS:
                return self.hbm.get(key, default), "hbm"
        v = self.dram.peek(key, MISS)
        if v is not MISS:
            value = self.dram.get(key, default)
            self._maybe_promote_hbm(key, value)
            return value, "dram"
        if self.spill is not None and key in self.spill:
            v = self.spill.get(key, MISS)   # counts the disk hit
            if v is not MISS:
                self._promote(key, v)
                return v, "disk"
            return default, None            # file vanished: disk miss
        self.dram.stats.misses += 1
        return default, None

    def peek(self, key: int, default: Any = None) -> Any:
        """Stats-neutral read: no hit/miss counting, no LRU promotion.
        For controller/refill scans that inspect residency without being
        part of the serving path."""
        if self.hbm is not None:
            v = self.hbm.peek(key, MISS)
            if v is not MISS:
                return v
        v = self.dram.peek(key, MISS)
        if v is not MISS:
            return v
        if self.spill is not None:
            return self.spill.peek(key, default)
        return default

    def _promote(self, key: int, value: Any) -> None:
        """Move a disk hit up to DRAM (LRU partitions make room by
        demoting their coldest entries back down; no-evict partitions
        promote only into free space — otherwise the entry stays on
        disk and keeps serving from there)."""
        nbytes = self.spill.size_of(key)
        if nbytes is None or not self.dram.admits(nbytes):
            return
        demoted = self.dram.put(key, value, nbytes)
        if key in self.dram:
            self.spill.discard(key)
            self.promotions += 1
        self._demote(demoted)

    def _demote(self, entries) -> None:
        """Push DRAM-evicted entries down into the spill tier; entries
        the spill cannot hold (and entries the spill evicts to make
        room) leave the chain and are queued for metadata patching.
        Without a spill tier nothing queues — chain-leavers are exactly
        the caller-visible eviction lists the pre-chain code returned,
        so no reconcile pass exists (or is needed) to drain them."""
        for k, _v, _nb in entries:
            # every entry here left DRAM: its promotion heat is stale
            # (a later re-entry must re-earn device residency) and the
            # map must not grow toward n_total over long runs
            self._heat.pop(k, None)
        if self.spill is None:
            return
        for k, v, nb in entries:
            placed = False
            if self.spill.admits(nb):
                for ek, _ev, _enb in self.spill.put(k, v, nb):
                    self.pending_evicted.append(ek)
                placed = k in self.spill
                if placed:
                    self.demotions += 1
            if not placed:
                self.pending_evicted.append(k)

    def _maybe_promote_hbm(self, key: int, value: Any) -> None:
        """Count a DRAM hit toward device promotion; on the
        ``HBM_PROMOTE_HITS``-th hit of an HBM-eligible payload, move it
        up (device_put) and cascade any HBM evictions back down."""
        if self.hbm is None or not HbmTier.wants_value(value):
            return
        heat = self._heat.get(key, 0) + 1
        if heat < self.HBM_PROMOTE_HITS:
            self._heat[key] = heat
            return
        self._heat.pop(key, None)
        entry = self.dram.pop_entry(key)
        if entry is None:
            return
        _v, nbytes = entry
        if not self.hbm.admits(nbytes):
            # oversized for the device tier: put it straight back (the
            # slot it just vacated is still free, so this cannot evict)
            self.dram.put(key, value, nbytes)
            return
        demoted = self.hbm.put(key, value, nbytes)
        if key in self.hbm:
            self.hbm_promotions += 1
        self._demote_hbm(demoted)

    def _demote_hbm(self, entries) -> None:
        """Push HBM-evicted entries down into DRAM as host copies,
        cascading DRAM overflow into the spill tier; entries nothing
        below can hold leave the chain (queued for metadata patching —
        unlike :meth:`_demote`, chain-leavers queue even without a
        spill tier, because HBM demotion happens during *lookups* where
        the caller sees no eviction list)."""
        for k, v, nb in entries:
            host = np.asarray(v)
            placed = False
            if self.dram.admits(nb):
                dram_evicted = self.dram.put(k, host, nb)
                placed = k in self.dram
                if placed:
                    self.hbm_demotions += 1
                if self.spill is None:
                    for ek, _ev, _enb in dram_evicted:
                        self._heat.pop(ek, None)
                        self.pending_evicted.append(ek)
                else:
                    self._demote(dram_evicted)
            if not placed:
                if self.spill is not None and self.spill.admits(nb):
                    for ek, _ev, _enb in self.spill.put(k, host, nb):
                        self.pending_evicted.append(ek)
                    placed = k in self.spill
                    if placed:
                        self.hbm_demotions += 1
                if not placed:
                    self.pending_evicted.append(k)

    # ------------------------------------------------------------------
    def admits(self, nbytes: int) -> bool:
        """Could an insert of ``nbytes`` land anywhere in the chain?"""
        if self.hbm is not None and self.hbm.admits(nbytes):
            return True
        if self.dram.admits(nbytes):
            return True
        return self.spill is not None and self.spill.admits(nbytes)

    def put(self, key: int, value: Any, nbytes: int) -> List[int]:
        """Insert; returns the keys evicted *out of the chain* (never
        evicts under 'none' — the insert overflows to the spill tier
        when one exists, or is rejected, MINIO-style).  Re-inserting an
        existing key replaces it.  Array payloads the device tier
        admits go HBM-resident immediately; HBM evictions cascade down
        the chain like any demotion."""
        if (self.hbm is not None and HbmTier.wants_value(value)
                and self.hbm.admits(nbytes)):
            demoted = self.hbm.put(key, value, nbytes)
            evicted: List[int] = []
            if key in self.hbm:
                # single-residence invariant across all three tiers
                self.dram.pop_entry(key)
                if self.spill is not None:
                    self.spill.discard(key)
                self._heat.pop(key, None)
                self._demote_hbm(demoted)
                evicted.extend(k for k, _v, _nb in demoted
                               if k not in self)
                return evicted
            # no-evict HBM rejected after all: fall through to DRAM
        demoted = self.dram.put(key, value, nbytes)
        evicted = []
        if key in self.dram:
            # single-residence invariant: a fresh DRAM copy supersedes
            # any stale spill (or device) copy from earlier demotions
            if self.spill is not None:
                self.spill.discard(key)
            if self.hbm is not None:
                self.hbm.remove(key)
        elif self.spill is not None:
            # DRAM rejected (no-evict policy full / oversized): spill
            # admission keeps the entry cached at disk speed
            for ek, _ev, _enb in self.spill.put(key, value, nbytes):
                self.pending_evicted.append(ek)
                evicted.append(ek)
        self._demote(demoted)
        evicted.extend(k for k, _v, _nb in demoted
                       if k not in self)
        return evicted

    def set_capacity(self, capacity_bytes: int) -> List[int]:
        """Resize the DRAM level live; returns the keys evicted out of
        the chain.  Shrinking demotes through the partition's own policy
        order — LRU order for "lru", insertion (FIFO) order for
        "none"/"refcount" — into the spill tier when one exists, rather
        than dropping.  Byte accounting stays exact per tier (asserted
        by tests/test_cache_properties.py)."""
        demoted = self.dram.set_capacity(capacity_bytes)
        self._demote(demoted)
        return [k for k, _v, _nb in demoted if k not in self]

    def set_spill_capacity(self, capacity_bytes: int) -> List[int]:
        """Resize the disk level live; spill shrink evictions are
        terminal."""
        if self.spill is None:
            return []
        evicted = [k for k, _v, _nb in
                   self.spill.set_capacity(capacity_bytes)]
        self.pending_evicted.extend(evicted)
        return evicted

    def set_hbm_capacity(self, capacity_bytes: int) -> List[int]:
        """Resize the device level live; shrink demotions cascade down
        the chain (host copies into DRAM, overflowing to disk) and the
        keys evicted out of the chain entirely are returned."""
        if self.hbm is None:
            return []
        demoted = self.hbm.set_capacity(capacity_bytes)
        self._demote_hbm(demoted)
        return [k for k, _v, _nb in demoted if k not in self]

    def remove(self, key: int) -> bool:
        """Drop ``key`` from every tier (refcount eviction consumes the
        sample entirely — a spilled or device copy must not resurrect
        it)."""
        dropped = self.dram.remove(key)
        if self.spill is not None and self.spill.remove(key):
            dropped = True
        if self.hbm is not None and self.hbm.remove(key):
            dropped = True
        self._heat.pop(key, None)
        return dropped

    def take_pending_evicted(self) -> List[int]:
        out = self.pending_evicted
        self.pending_evicted = []
        return out


class _StripeLockSet:
    """``cache.lock`` for a striped cache: acquiring it takes every
    stripe lock in ascending index order — the single global order all
    whole-cache operations use, so two whole-cache ops can never
    deadlock against each other — and holding it excludes all per-key
    traffic on every stripe."""

    def __init__(self, locks: List[threading.Lock]):
        self._locks = locks

    def acquire(self) -> None:
        for lk in self._locks:
            lk.acquire()

    def release(self) -> None:
        for lk in reversed(self._locks):
            lk.release()

    def __enter__(self) -> "_StripeLockSet":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class _StripedFormView:
    """Read-mostly aggregate over one form's per-stripe partitions —
    what ``cache.parts[form]`` returns when ``n_stripes > 1``, so
    telemetry/diagnostic readers (shard ``_op_stats``, tests, notebook
    pokes) keep working against the striped layout.

    Point reads route by key hash; ledger/stat properties merge the
    stripe-local counters on read (unlocked, like the single-stripe
    counter reads they replace).  Callers that need a cross-stripe
    consistent view must hold ``cache.lock`` (all stripes)."""

    def __init__(self, form: str,
                 stripes: List[Dict[str, CachePartition]]):
        self.form = form
        self._parts = [s[form] for s in stripes]

    def _of(self, key: int) -> CachePartition:
        return self._parts[int(key) % len(self._parts)]

    # -- point reads (route to the owning stripe) ----------------------
    def __contains__(self, key: int) -> bool:
        return key in self._of(key)

    def peek(self, key: int, default: Any = None) -> Any:
        return self._of(key).peek(key, default)

    def tier_of(self, key: int) -> Optional[str]:
        return self._of(key).tier_of(key)

    # -- merged ledgers / stats ----------------------------------------
    def __len__(self) -> int:
        return sum(len(p) for p in self._parts)

    def keys(self) -> List[int]:
        ks: List[int] = []
        for p in self._parts:
            ks += p.keys()
        return ks

    @property
    def capacity(self) -> int:
        return sum(p.capacity for p in self._parts)

    @property
    def total_capacity(self) -> int:
        return sum(p.total_capacity for p in self._parts)

    @property
    def free_bytes(self) -> int:
        return sum(p.free_bytes for p in self._parts)

    @property
    def policy(self) -> str:
        return self._parts[0].policy

    @property
    def stats(self) -> PartitionStats:
        return PartitionStats.merged([p.stats for p in self._parts])

    @property
    def total_hits(self) -> int:
        return sum(p.total_hits for p in self._parts)

    @property
    def total_misses(self) -> int:
        return sum(p.total_misses for p in self._parts)

    @property
    def promotions(self) -> int:
        return sum(p.promotions for p in self._parts)

    @property
    def demotions(self) -> int:
        return sum(p.demotions for p in self._parts)

    @property
    def hbm_promotions(self) -> int:
        return sum(p.hbm_promotions for p in self._parts)

    @property
    def hbm_demotions(self) -> int:
        return sum(p.hbm_demotions for p in self._parts)

    @property
    def pending_evicted(self) -> List[int]:
        out: List[int] = []
        for p in self._parts:
            out.extend(p.pending_evicted)
        return out

    @property
    def _data(self) -> Dict[int, Any]:
        out: Dict[int, Any] = {}
        for p in self._parts:
            out.update(p._data)
        return out

    @property
    def _sizes(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for p in self._parts:
            out.update(p._sizes)
        return out


class TieredCache:
    """The Seneca cache: three partitions sized by an MDP split, each an
    optional HBM→DRAM→disk tier chain sized by the form×tier MDP, with
    the key space optionally hash-striped over ``n_stripes``
    independent lock domains (module doc)."""

    def __init__(self, capacity_bytes: int,
                 split: Tuple[float, float, float],
                 evict_policies: Optional[Dict[str, str]] = None,
                 spill_bytes: int = 0,
                 spill_dir: Optional[str] = None,
                 spill_split: Optional[Tuple[float, float, float]] = None,
                 hbm_bytes: int = 0,
                 hbm_split: Optional[Tuple[float, float, float]] = None,
                 n_stripes: int = 1):
        x_e, x_d, x_a = split
        assert abs(x_e + x_d + x_a - 1.0) < 1e-6, split
        pol = evict_policies or {"encoded": "none", "decoded": "none",
                                 "augmented": "refcount"}
        self.capacity = capacity_bytes
        self.split = split
        self.n_stripes = max(1, int(n_stripes))
        self.spill_bytes = int(spill_bytes) if spill_dir else 0
        self.spill_dir = spill_dir if self.spill_bytes > 0 else None
        if self.spill_dir is not None:
            self.spill_split = tuple(spill_split) if spill_split \
                else tuple(split)
            y_e, y_d, y_a = self.spill_split
            assert abs(y_e + y_d + y_a - 1.0) < 1e-6, self.spill_split
        else:
            self.spill_split = None
        self.hbm_bytes = int(hbm_bytes)
        if self.hbm_bytes > 0:
            self.hbm_split = tuple(hbm_split) if hbm_split \
                else tuple(split)
            z_e, z_d, z_a = self.hbm_split
            assert abs(z_e + z_d + z_a - 1.0) < 1e-6, self.hbm_split
        else:
            self.hbm_split = None
        self._stripes: List[Dict[str, CachePartition]] = [
            self._build_stripe(i, pol) for i in range(self.n_stripes)]
        self._locks: List[threading.Lock] = [
            threading.Lock() for _ in range(self.n_stripes)]
        if self.n_stripes == 1:
            # exact legacy surface: `parts` IS the partition dict and
            # `lock` IS the one hot-path lock, so every existing direct
            # poke (tests, notebooks) behaves byte-identically
            self.parts: Dict[str, CachePartition] = self._stripes[0]
            self.lock = self._locks[0]
        else:
            self.parts = {form: _StripedFormView(form, self._stripes)
                          for form in FORMS}
            self.lock = _StripeLockSet(self._locks)
        self._closed = False
        # stripe-local ledgers aggregated on read (the `lookup_misses`
        # / `version` properties) so the hot path never shares a
        # counter cache line across stripes.
        # misses counted at lookup granularity: a key absent from every
        # partition is ONE miss, not zero and not three
        self._lookup_misses: List[int] = [0] * self.n_stripes
        # bumped on every mutation that can change residency (insert,
        # evict, resize, disk-hit promotion) so the service can skip
        # rebuilding the O(N) residency array when nothing moved
        self._versions: List[int] = [0] * self.n_stripes

    # -- striped construction ------------------------------------------
    def _stripe_share(self, total: int, i: int) -> int:
        """Stripe ``i``'s byte share of ``total`` (remainder to stripe
        0; with one stripe this is ``total`` exactly)."""
        base, rem = divmod(int(total), self.n_stripes)
        return base + (rem if i == 0 else 0)

    def _stripe_spill_root(self, i: int) -> Optional[str]:
        """Stripe 0 spills into ``spill_dir`` itself (the legacy
        layout); stripe ``i>0`` into ``spill_dir/s<i>``."""
        if self.spill_dir is None:
            return None
        return self.spill_dir if i == 0 \
            else os.path.join(self.spill_dir, f"s{i}")

    def _build_stripe(self, i: int,
                      pol: Dict[str, str]) -> Dict[str, CachePartition]:
        if self.spill_dir is not None:
            spill_cap = self._stripe_share(self.spill_bytes, i)
            root = self._stripe_spill_root(i)
            spills = {form: DiskTier(int(y * spill_cap), root, form)
                      for form, y in zip(FORMS, self.spill_split)}
        else:
            spills = {form: None for form in FORMS}
        if self.hbm_bytes > 0:
            hbm_cap = self._stripe_share(self.hbm_bytes, i)
            # LRU on device: HBM is small and hot — coldest array falls
            # back to DRAM rather than blocking new promotions
            hbms = {form: HbmTier(int(z * hbm_cap), "lru")
                    for form, z in zip(FORMS, self.hbm_split)}
        else:
            hbms = {form: None for form in FORMS}
        cap = self._stripe_share(self.capacity, i)
        return {form: CachePartition(int(x * cap), pol[form],
                                     spills[form], hbms[form])
                for form, x in zip(FORMS, self.split)}

    def _stripe_of(self, key: int) -> int:
        return int(key) % self.n_stripes

    def _group_by_stripe(self, keys) -> List[Tuple[int, List[int]]]:
        """Bucket positions of ``keys`` by owning stripe, ascending
        stripe order (one bucket — original iteration order — when
        unstriped), so batch ops lock each stripe exactly once."""
        if self.n_stripes == 1:
            return [(0, list(range(len(keys))))]
        by: Dict[int, List[int]] = {}
        for i, k in enumerate(keys):
            by.setdefault(int(k) % self.n_stripes, []).append(i)
        return sorted(by.items())

    # ------------------------------------------------------------------
    @property
    def has_spill(self) -> bool:
        return self.spill_dir is not None

    @property
    def has_hbm(self) -> bool:
        return self.hbm_bytes > 0

    @property
    def lookup_misses(self) -> int:
        return sum(self._lookup_misses)

    @property
    def version(self) -> int:
        return sum(self._versions)

    def _flush_spill(self, stripe: Optional[int] = None) -> None:
        """Drain staged write-behind spill payloads, releasing the
        stripe's lock around each file write
        (:meth:`DiskTier.flush_staged`).  Called *after* the lock is
        dropped by every mutating public method, so op boundaries
        observe index == files-on-disk.  Per-key ops pass their stripe;
        whole-cache ops drain every stripe."""
        if not self.has_spill:
            return
        stripes = range(self.n_stripes) if stripe is None else (stripe,)
        for s in stripes:
            lock = self._locks[s]
            for part in self._stripes[s].values():
                part.spill.flush_staged(lock)

    def lookup(self, key: int) -> Tuple[Optional[str], Any]:
        """Most-processed form first (augmented > decoded > encoded)."""
        form, value, _tier = self.lookup_tiered(key)
        return form, value

    def lookup_tiered(self, key: int
                      ) -> Tuple[Optional[str], Any, Optional[str]]:
        """Like :meth:`lookup` but also names the tier that answered
        ("hbm" | "dram" | "disk" | None) so telemetry can track
        per-tier serve bandwidths."""
        s = self._stripe_of(key)
        try:
            with self._locks[s]:
                parts = self._stripes[s]
                for form in ("augmented", "decoded", "encoded"):
                    part = parts[form]
                    if key in part:
                        promos = part.promotions + part.hbm_promotions
                        value, tier = part.get_tiered(key, MISS)
                        if value is not MISS:
                            # only an actual promotion changes residency;
                            # a disk hit that stays on disk must not
                            # defeat the version-gated residency rebuild
                            if (part.promotions
                                    + part.hbm_promotions != promos):
                                self._versions[s] += 1
                            return form, value, tier
                self._lookup_misses[s] += 1
                return None, None, None
        finally:
            # promotions can cascade demotions into the spill stage
            self._flush_spill(s)

    def insert(self, key: int, form: str, value: Any, nbytes: int) -> bool:
        """Insert; True when the key is resident afterwards."""
        s = self._stripe_of(key)
        with self._locks[s]:
            self._versions[s] += 1
            part = self._stripes[s][form]
            part.put(key, value, nbytes)
            resident = key in part
        self._flush_spill(s)
        return resident

    def insert_gated(self, key: int, form: str, value: Any, nbytes: int,
                     policy) -> bool:
        """Insert with the admission policy's capacity vote evaluated under
        the stripe lock, atomically with the put — concurrent workers cannot
        both pass a stale free-bytes check."""
        s = self._stripe_of(key)
        with self._locks[s]:
            part = self._stripes[s][form]
            if not policy.fits(part, nbytes):
                return False
            self._versions[s] += 1
            part.put(key, value, nbytes)
            resident = key in part
        self._flush_spill(s)
        return resident

    def insert_batch_gated(self, form: str, entries, policy) -> List[bool]:
        """Batch-granular admission: ``entries`` is a sequence of
        ``(key, value, nbytes)``; the capacity vote + insert for each
        stripe's slice of the batch runs under ONE acquisition of that
        stripe's lock (the stage-parallel pipeline's per-batch admission
        — vs one acquisition per sample).

        Per-entry semantics are identical to :meth:`insert_gated`: each
        entry is voted with the partition state the previous entries
        left behind — a rejected entry does NOT reject the rest, so a
        later, smaller entry may still fit (same results as N looped
        ``insert_gated`` calls).  Returns one bool per entry.
        """
        entries = list(entries)
        out: List[bool] = [False] * len(entries)
        for s, idxs in self._group_by_stripe([e[0] for e in entries]):
            with self._locks[s]:
                part = self._stripes[s][form]
                for i in idxs:
                    key, value, nbytes = entries[i]
                    if not policy.fits(part, nbytes):
                        continue
                    self._versions[s] += 1
                    part.put(key, value, nbytes)
                    out[i] = key in part
            self._flush_spill(s)
        return out

    def evict(self, key: int, form: str) -> bool:
        s = self._stripe_of(key)
        with self._locks[s]:
            self._versions[s] += 1
            return self._stripes[s][form].remove(key)

    def peek(self, key: int) -> Tuple[Optional[str], Any]:
        """Stats-neutral lookup (same tier order), for controller/refill
        scans — ``lookup`` would inflate miss counts.  Loads spilled
        payloads from disk; callers that only need the *form* should use
        :meth:`form_of` (containment-only, no IO under the lock)."""
        s = self._stripe_of(key)
        with self._locks[s]:
            for form in ("augmented", "decoded", "encoded"):
                part = self._stripes[s][form]
                if key in part:
                    return form, part.peek(key)
            return None, None

    def form_of(self, key: int) -> Optional[str]:
        """The form a lookup would serve (most-processed resident), by
        containment only — no payload read, no stats, no promotion."""
        s = self._stripe_of(key)
        with self._locks[s]:
            for form in ("augmented", "decoded", "encoded"):
                if key in self._stripes[s][form]:
                    return form
            return None

    # -- containment / capacity queries --------------------------------
    # The service layer's window onto the cache.  These (not `parts` /
    # `lock` pokes) are the contract a drop-in cache implementation —
    # e.g. the sharded service client — must satisfy.

    def contains(self, form: str, key: int) -> bool:
        """Is ``key`` resident (any tier) in ``form``'s partition?"""
        s = self._stripe_of(key)
        with self._locks[s]:
            return key in self._stripes[s][form]

    def contains_many(self, form: str, keys) -> List[bool]:
        """Batch :meth:`contains`, one lock acquisition per touched
        stripe."""
        keys = list(keys)
        out: List[bool] = [False] * len(keys)
        for s, idxs in self._group_by_stripe(keys):
            with self._locks[s]:
                part = self._stripes[s][form]
                for i in idxs:
                    out[i] = keys[i] in part
        return out

    def serving_forms(self, keys) -> List[Optional[str]]:
        """Batch :meth:`form_of`, one lock acquisition per touched
        stripe: per key, the most-processed resident form (or None)."""
        keys = list(keys)
        out: List[Optional[str]] = [None] * len(keys)
        for s, idxs in self._group_by_stripe(keys):
            with self._locks[s]:
                parts = self._stripes[s]
                for i in idxs:
                    for form in ("augmented", "decoded", "encoded"):
                        if keys[i] in parts[form]:
                            out[i] = form
                            break
        return out

    def total_capacity(self, form: str) -> int:
        """DRAM + spill capacity of ``form``'s tier chain (bytes)."""
        return sum(s[form].total_capacity for s in self._stripes)

    def chain_free_bytes(self, form: str) -> int:
        """Free bytes across ``form``'s whole tier chain."""
        free = 0
        for s in range(self.n_stripes):
            with self._locks[s]:
                part = self._stripes[s][form]
                free += part.free_bytes
                if part.spill is not None:
                    free += part.spill.free_bytes
                if part.hbm is not None:
                    free += part.hbm.free_bytes
        return free

    def set_form_costs(self, costs: Dict[str, float]) -> None:
        """Push telemetry-measured recompute costs (seconds per entry)
        into each form's "cost"-policy DRAM tier; no-op for other
        policies (the GDSF eviction satellite's feedback path)."""
        with self.lock:
            for form, cost in costs.items():
                for stripe in self._stripes:
                    dram = stripe[form].dram
                    if dram.policy == "cost" and cost and cost > 0:
                        dram.set_cost(float(cost))

    def take_evicted(self) -> List[int]:
        """Drain the keys the chains evicted as a side effect (spill
        overflow, promotion backfill) since the last drain — the service
        patches ODS metadata with them (reconcile_evictions)."""
        with self.lock:
            out: List[int] = []
            for stripe in self._stripes:
                for part in stripe.values():
                    out.extend(part.take_pending_evicted())
            return out

    def has_pending_evicted(self) -> bool:
        with self.lock:
            return any(part.pending_evicted
                       for stripe in self._stripes
                       for part in stripe.values())

    def resize(self, split: Tuple[float, float, float],
               spill_split: Optional[Tuple[float, float, float]] = None,
               hbm_split: Optional[Tuple[float, float, float]] = None
               ) -> Dict[str, List[int]]:
        """Re-partition the same total capacity live, under every
        stripe lock (ascending order — a whole-cache op).

        Shrinking partitions evict (policy order) down to their new
        capacity; growing ones just gain headroom.  Shrinks are applied
        before grows so the instantaneous sum of partition capacities
        never exceeds the total.  With a spill tier, DRAM shrink
        evictions demote to disk, and ``spill_split`` (defaulting to
        ``split``) resizes the disk level the same way — disk grows
        first so demotion traffic lands in the enlarged tiers, disk
        shrinks last.  With a device tier, ``hbm_split`` resizes the
        HBM level: HBM shrinks before the DRAM pass (demotions land in
        the still-sized DRAM/disk tiers) and grows after it.  Returns
        ``{form: [keys evicted out of the chain]}`` so the caller can
        demote/patch ODS metadata.
        """
        x_e, x_d, x_a = split
        if abs(x_e + x_d + x_a - 1.0) >= 1e-6:
            raise ValueError(f"split must sum to 1: {split}")
        evicted: Dict[str, List[int]] = {}

        def add(form: str, keys: List[int]) -> None:
            if keys:
                evicted.setdefault(form, []).extend(keys)

        with self.lock:
            ys = zs = None
            if self.has_spill:
                ys = tuple(spill_split) if spill_split is not None \
                    else (float(x_e), float(x_d), float(x_a))
                if abs(sum(ys) - 1.0) >= 1e-6:
                    raise ValueError(
                        f"spill_split must sum to 1: {ys}")
            if self.has_hbm:
                zs = tuple(hbm_split) if hbm_split is not None \
                    else (float(x_e), float(x_d), float(x_a))
                if abs(sum(zs) - 1.0) >= 1e-6:
                    raise ValueError(
                        f"hbm_split must sum to 1: {zs}")
            for s, parts in enumerate(self._stripes):
                cap = self._stripe_share(self.capacity, s)
                targets = {form: int(x * cap)
                           for form, x in zip(FORMS, (x_e, x_d, x_a))}
                disk_targets = None
                if ys is not None:
                    spill_cap = self._stripe_share(self.spill_bytes, s)
                    disk_targets = {f: int(y * spill_cap)
                                    for f, y in zip(FORMS, ys)}
                    # disk grows first: DRAM-shrink demotions flow into
                    # the enlarged spill tiers instead of being dropped
                    for form in FORMS:
                        part = parts[form]
                        if disk_targets[form] >= part.spill.capacity:
                            add(form, part.set_spill_capacity(
                                disk_targets[form]))
                hbm_targets = None
                if zs is not None:
                    hbm_cap = self._stripe_share(self.hbm_bytes, s)
                    hbm_targets = {f: int(z * hbm_cap)
                                   for f, z in zip(FORMS, zs)}
                    # HBM shrinks before the DRAM pass so device
                    # demotions land in tiers with their old headroom
                    for form in FORMS:
                        part = parts[form]
                        if hbm_targets[form] < part.hbm.capacity:
                            add(form, part.set_hbm_capacity(
                                hbm_targets[form]))
                order = sorted(
                    FORMS, key=lambda f: targets[f] - parts[f].capacity)
                for form in order:        # shrinks first, then grows
                    add(form, parts[form].set_capacity(targets[form]))
                if hbm_targets is not None:  # HBM grows after DRAM pass
                    for form in FORMS:
                        part = parts[form]
                        if hbm_targets[form] >= part.hbm.capacity:
                            add(form, part.set_hbm_capacity(
                                hbm_targets[form]))
                if disk_targets is not None:  # disk shrinks last
                    for form in FORMS:
                        part = parts[form]
                        if disk_targets[form] < part.spill.capacity:
                            add(form, part.set_spill_capacity(
                                disk_targets[form]))
            if ys is not None:
                self.spill_split = tuple(float(y) for y in ys)
            if zs is not None:
                self.hbm_split = tuple(float(z) for z in zs)
            self.split = (float(x_e), float(x_d), float(x_a))
            self._versions[0] += 1
        self._flush_spill()
        return evicted

    def status_array(self, n: int) -> np.ndarray:
        """uint8[N] of ODS status codes (0 storage / 1 enc / 2 dec / 3
        aug); disk-resident entries keep their form's code — residency
        *level* is :meth:`residency_array`'s job.

        Key lists are snapshotted under each stripe lock; the O(N)
        scatter runs with the locks released, so this scan no longer
        stalls concurrent serving threads."""
        snaps: List[Tuple[int, List[int]]] = []
        for s in range(self.n_stripes):
            with self._locks[s]:
                parts = self._stripes[s]
                for code, form in ((1, "encoded"), (2, "decoded"),
                                   (3, "augmented")):
                    ks = parts[form].keys()
                    if ks:
                        snaps.append((code, ks))
        out = np.zeros(n, np.uint8)
        for code, ks in snaps:
            out[np.asarray(ks, int)] = code
        return out

    def residency_array(self, n: int) -> np.ndarray:
        """uint8[N] residency levels: 0 = storage only, 1 = disk,
        2 = DRAM, 3 = HBM — of the form a lookup would actually serve
        (the most-processed resident form), not the best tier over all
        forms: a sample whose augmented copy spilled to disk serves at
        disk latency even if its encoded copy sits in DRAM.  Feeds the
        ODS substitution preference (device hits beat DRAM hits beat
        disk hits beat storage misses).

        Like :meth:`status_array`, snapshots key lists under the stripe
        locks and builds the array outside them (keys live on exactly
        one stripe, so the out-of-lock scatter cannot interleave two
        stripes' claims to one slot)."""
        snaps: List[Tuple[int, List[int]]] = []
        for s in range(self.n_stripes):
            with self._locks[s]:
                # lowest serving priority first; higher-priority forms
                # overwrite, so each sample ends at its serving form's
                # tier (within a form the tiers are disjoint)
                for form in ("encoded", "decoded", "augmented"):
                    part = self._stripes[s][form]
                    if part.spill is not None:
                        ks = part.spill.keys()
                        if ks:
                            snaps.append((RESIDENCY_DISK, ks))
                    ks = part.dram.keys()
                    if ks:
                        snaps.append((RESIDENCY_DRAM, ks))
                    if part.hbm is not None:
                        ks = part.hbm.keys()
                        if ks:
                            snaps.append((RESIDENCY_HBM, ks))
        out = np.zeros(n, np.uint8)
        for level, ks in snaps:
            out[np.asarray(ks, int)] = level
        return out

    # -- unlocked aggregate reads --------------------------------------
    def _all_parts(self):
        for stripe in self._stripes:
            for part in stripe.values():
                yield part

    def hit_rate(self) -> float:
        h = sum(p.total_hits for p in self._all_parts())
        m = sum(p.total_misses
                for p in self._all_parts()) + self.lookup_misses
        return h / (h + m) if h + m else 0.0

    def bytes_used(self) -> int:
        return sum(p.stats.bytes_used for p in self._all_parts())

    def disk_bytes_used(self) -> int:
        return sum(p.spill.stats.bytes_used for p in self._all_parts()
                   if p.spill is not None)

    def hbm_bytes_used(self) -> int:
        return sum(p.hbm.stats.bytes_used for p in self._all_parts()
                   if p.hbm is not None)

    def hbm_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-form device-tier traffic, summed over stripes
        (JSON-friendly; empty without an HBM tier)."""
        if not self.has_hbm:
            return {}
        agg = {form: {"hbm_bytes_used": 0, "hbm_capacity": 0,
                      "hbm_entries": 0, "hbm_hits": 0,
                      "hbm_promotions": 0, "hbm_demotions": 0}
               for form in FORMS}
        for s in range(self.n_stripes):
            with self._locks[s]:
                for form, part in self._stripes[s].items():
                    d = agg[form]
                    d["hbm_bytes_used"] += part.hbm.stats.bytes_used
                    d["hbm_capacity"] += part.hbm.capacity
                    d["hbm_entries"] += len(part.hbm)
                    d["hbm_hits"] += part.hbm.stats.hits
                    d["hbm_promotions"] += part.hbm_promotions
                    d["hbm_demotions"] += part.hbm_demotions
        return agg

    def spill_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-form chain traffic, summed over stripes (JSON-friendly;
        empty without spill)."""
        if not self.has_spill:
            return {}
        agg = {form: {"disk_bytes_used": 0, "disk_capacity": 0,
                      "disk_entries": 0, "disk_hits": 0,
                      "demotions": 0, "promotions": 0, "io_errors": 0}
               for form in FORMS}
        for s in range(self.n_stripes):
            with self._locks[s]:
                for form, part in self._stripes[s].items():
                    d = agg[form]
                    d["disk_bytes_used"] += part.spill.stats.bytes_used
                    d["disk_capacity"] += part.spill.capacity
                    d["disk_entries"] += len(part.spill)
                    d["disk_hits"] += part.spill.stats.hits
                    d["demotions"] += part.demotions
                    d["promotions"] += part.promotions
                    d["io_errors"] += part.spill.io_errors
        return agg

    def close(self) -> None:
        """Tear down the spill area: every entry file is unlinked, the
        per-form directories removed, and (striped) the per-stripe
        subroots removed (the no-leaked-files contract asserted by the
        tiered-cache benchmark and CI).

        Idempotent and exception-safe: shard teardown reaches here from
        several paths (transport close, failed server construction,
        ``with`` exits), so a second call is a no-op and an OSError
        from one form's cleanup doesn't abort the others."""
        with self.lock:
            if self._closed:
                return
            failed = False
            for part in self._all_parts():
                if part.spill is not None:
                    try:
                        part.spill.clear()
                    except OSError:
                        part.spill.io_errors += 1
                        failed = True
            if not failed and self.has_spill and self.n_stripes > 1:
                for s in range(1, self.n_stripes):
                    try:
                        os.rmdir(self._stripe_spill_root(s))
                    except OSError:
                        failed = True
            # only latch closed once every spill dir actually emptied,
            # so a transient IO failure can be retried by a later close
            self._closed = not failed
