"""Three-form in-memory cache (the Redis analogue, DESIGN.md §2).

Byte-accounted partitions for encoded / decoded / augmented samples with
pluggable eviction.  Thread-safe: the real data pipeline hits this store
from fetch worker threads while the trainer consumes batches.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

FORMS = ("encoded", "decoded", "augmented")


@dataclass
class PartitionStats:
    hits: int = 0
    misses: int = 0
    inserts: int = 0
    evictions: int = 0
    bytes_used: int = 0


class CachePartition:
    """One form's partition: id -> value with byte accounting + LRU order."""

    def __init__(self, capacity_bytes: int, evict_policy: str = "none"):
        assert evict_policy in ("none", "lru", "refcount")
        self.capacity = int(capacity_bytes)
        self.policy = evict_policy
        self._data: "OrderedDict[int, Any]" = OrderedDict()
        self._sizes: Dict[int, int] = {}
        self.stats = PartitionStats()

    def __contains__(self, key: int) -> bool:
        return key in self._data

    def __len__(self) -> int:
        return len(self._data)

    def keys(self) -> List[int]:
        return list(self._data.keys())

    def get(self, key: int):
        v = self._data.get(key)
        if v is None:
            self.stats.misses += 1
            return None
        self.stats.hits += 1
        if self.policy == "lru":
            self._data.move_to_end(key)
        return v

    def peek(self, key: int):
        """Stats-neutral read: no hit/miss counting, no LRU promotion.
        For controller/refill scans that inspect residency without being
        part of the serving path."""
        return self._data.get(key)

    def put(self, key: int, value: Any, nbytes: int) -> List[int]:
        """Insert; returns evicted keys (never evicts under 'none' — the
        insert is rejected instead, MINIO-style).  Re-inserting an existing
        key replaces it (the old entry is dropped first, so a rejected
        oversized replacement leaves the key absent, not half-accounted)."""
        evicted: List[int] = []
        if key in self._data:
            del self._data[key]
            self.stats.bytes_used -= self._sizes.pop(key)
        while self.stats.bytes_used + nbytes > self.capacity:
            if self.policy == "lru" and self._data:
                k, _ = self._data.popitem(last=False)
                self.stats.bytes_used -= self._sizes.pop(k)
                self.stats.evictions += 1
                evicted.append(k)
            else:
                return evicted           # rejected (no-evict policy)
        self._data[key] = value
        self._sizes[key] = nbytes
        self.stats.bytes_used += nbytes
        self.stats.inserts += 1
        return evicted

    def set_capacity(self, capacity_bytes: int) -> List[int]:
        """Resize the partition live; returns the keys evicted to fit.

        Shrinking below current usage evicts through the partition's own
        policy order — LRU order for "lru", insertion (FIFO) order for
        "none"/"refcount" — rather than dropping the store.  Byte
        accounting stays exact (asserted by tests/test_repartition.py).
        """
        self.capacity = int(capacity_bytes)
        evicted: List[int] = []
        while self.stats.bytes_used > self.capacity and self._data:
            k, _ = self._data.popitem(last=False)
            self.stats.bytes_used -= self._sizes.pop(k)
            self.stats.evictions += 1
            evicted.append(k)
        return evicted

    def remove(self, key: int) -> bool:
        if key in self._data:
            del self._data[key]
            self.stats.bytes_used -= self._sizes.pop(key)
            self.stats.evictions += 1
            return True
        return False

    @property
    def free_bytes(self) -> int:
        return self.capacity - self.stats.bytes_used


class TieredCache:
    """The Seneca cache: three partitions sized by an MDP split."""

    def __init__(self, capacity_bytes: int,
                 split: Tuple[float, float, float],
                 evict_policies: Optional[Dict[str, str]] = None):
        x_e, x_d, x_a = split
        assert abs(x_e + x_d + x_a - 1.0) < 1e-6, split
        pol = evict_policies or {"encoded": "none", "decoded": "none",
                                 "augmented": "refcount"}
        self.capacity = capacity_bytes
        self.split = split
        self.parts: Dict[str, CachePartition] = {
            "encoded": CachePartition(int(x_e * capacity_bytes),
                                      pol["encoded"]),
            "decoded": CachePartition(int(x_d * capacity_bytes),
                                      pol["decoded"]),
            "augmented": CachePartition(int(x_a * capacity_bytes),
                                        pol["augmented"]),
        }
        self.lock = threading.Lock()
        # misses counted at lookup granularity: a key absent from every
        # partition is ONE miss, not zero (the partitions are only probed
        # via __contains__) and not three
        self.lookup_misses = 0

    def lookup(self, key: int) -> Tuple[Optional[str], Any]:
        """Most-processed form first (augmented > decoded > encoded)."""
        with self.lock:
            for form in ("augmented", "decoded", "encoded"):
                part = self.parts[form]
                if key in part:
                    return form, part.get(key)
            self.lookup_misses += 1
            return None, None

    def insert(self, key: int, form: str, value: Any, nbytes: int) -> bool:
        """Insert; True when the key is resident afterwards."""
        with self.lock:
            self.parts[form].put(key, value, nbytes)
            return key in self.parts[form]

    def insert_gated(self, key: int, form: str, value: Any, nbytes: int,
                     policy) -> bool:
        """Insert with the admission policy's capacity vote evaluated under
        the cache lock, atomically with the put — concurrent workers cannot
        both pass a stale free-bytes check."""
        with self.lock:
            part = self.parts[form]
            if not policy.fits(part, nbytes):
                return False
            part.put(key, value, nbytes)
            return key in part

    def insert_batch_gated(self, form: str, entries, policy) -> List[bool]:
        """Batch-granular admission: ``entries`` is a sequence of
        ``(key, value, nbytes)``; the capacity vote + insert for the whole
        batch run under ONE cache-lock acquisition (the stage-parallel
        pipeline's per-batch admission — vs one acquisition per sample).

        Per-entry semantics are identical to :meth:`insert_gated`: each
        entry is voted with the partition state the previous entries
        left behind — a rejected entry does NOT reject the rest, so a
        later, smaller entry may still fit (same results as N looped
        ``insert_gated`` calls).  Returns one bool per entry.
        """
        out: List[bool] = []
        with self.lock:
            part = self.parts[form]
            for key, value, nbytes in entries:
                if not policy.fits(part, nbytes):
                    out.append(False)
                    continue
                part.put(key, value, nbytes)
                out.append(key in part)
        return out

    def evict(self, key: int, form: str) -> bool:
        with self.lock:
            return self.parts[form].remove(key)

    def peek(self, key: int) -> Tuple[Optional[str], Any]:
        """Stats-neutral lookup (same tier order), for controller/refill
        scans — ``lookup`` would inflate miss counts."""
        with self.lock:
            for form in ("augmented", "decoded", "encoded"):
                v = self.parts[form].peek(key)
                if v is not None:
                    return form, v
            return None, None

    def resize(self, split: Tuple[float, float, float]
               ) -> Dict[str, List[int]]:
        """Re-partition the same total capacity live under the cache lock.

        Shrinking partitions evict (policy order) down to their new
        capacity; growing ones just gain headroom.  Shrinks are applied
        before grows so the instantaneous sum of partition capacities
        never exceeds the total.  Returns ``{form: [evicted keys]}`` so
        the caller can demote/patch ODS metadata.
        """
        x_e, x_d, x_a = split
        if abs(x_e + x_d + x_a - 1.0) >= 1e-6:
            raise ValueError(f"split must sum to 1: {split}")
        targets = {"encoded": int(x_e * self.capacity),
                   "decoded": int(x_d * self.capacity),
                   "augmented": int(x_a * self.capacity)}
        evicted: Dict[str, List[int]] = {}
        with self.lock:
            order = sorted(FORMS,
                           key=lambda f: targets[f] - self.parts[f].capacity)
            for form in order:            # shrinks first, then grows
                out = self.parts[form].set_capacity(targets[form])
                if out:
                    evicted[form] = out
            self.split = (float(x_e), float(x_d), float(x_a))
        return evicted

    def status_array(self, n: int) -> np.ndarray:
        """uint8[N] of ODS status codes (0 storage / 1 enc / 2 dec / 3 aug)."""
        out = np.zeros(n, np.uint8)
        with self.lock:
            for code, form in ((1, "encoded"), (2, "decoded"),
                               (3, "augmented")):
                ks = self.parts[form].keys()
                if ks:
                    out[np.asarray(ks, int)] = code
        return out

    def hit_rate(self) -> float:
        h = sum(p.stats.hits for p in self.parts.values())
        m = sum(p.stats.misses
                for p in self.parts.values()) + self.lookup_misses
        return h / (h + m) if h + m else 0.0

    def bytes_used(self) -> int:
        return sum(p.stats.bytes_used for p in self.parts.values())
