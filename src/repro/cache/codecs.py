"""Per-form serialization codecs for the disk cache tier.

The DRAM tier stores live Python objects; the disk tier stores one file
per entry.  What a file holds depends on the data form:

* ``encoded`` entries are already storage-shaped ``bytes`` — they pass
  through unmodified (one ``write``, one ``read``);
* ``decoded`` / ``augmented`` entries are ndarrays — they are written as
  their raw contiguous buffer and read back through ``np.memmap``, so a
  disk hit maps the file instead of copying it (the pages fault in
  lazily and stay in the OS page cache across repeated hits).

Codecs keep the entry *metadata* (dtype/shape) in memory — the disk tier
is a process-local spill area, not a persistent store, so nothing needs
to survive a restart.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Any, Protocol, Tuple, runtime_checkable

import numpy as np


@runtime_checkable
class Codec(Protocol):
    """Serializes one cache entry to/from a file."""

    name: str

    def dump(self, value: Any, path: str) -> Tuple[int, Any]:
        """Write ``value`` to ``path``; returns (bytes written, meta)."""
        ...

    def load(self, path: str, meta: Any) -> Any:
        """Read the entry back (zero-copy where the form allows)."""
        ...


class BytesCodec:
    """Pass-through for encoded samples (they are bytes on storage too)."""

    name = "bytes"

    def dump(self, value, path: str) -> Tuple[int, Any]:
        buf = bytes(value)
        with open(path, "wb") as f:
            f.write(buf)
        return len(buf), None

    def load(self, path: str, meta) -> bytes:
        with open(path, "rb") as f:
            return f.read()


class NdarrayCodec:
    """Raw contiguous buffer + in-memory (dtype, shape) metadata.

    ``load`` returns a read-only ``np.memmap`` view of the file — the
    zero-copy contract: promoting a disk hit back to DRAM hands the
    mapped array up the chain, and unlinking the file afterwards is safe
    (the mapping keeps the pages live until dropped).
    """

    name = "ndarray"

    def dump(self, value, path: str) -> Tuple[int, Any]:
        arr = np.ascontiguousarray(value)
        arr.tofile(path)
        return arr.nbytes, (arr.dtype, arr.shape)

    def load(self, path: str, meta) -> np.ndarray:
        dtype, shape = meta
        if int(np.prod(shape)) == 0:        # memmap rejects empty files
            return np.empty(shape, dtype)
        return np.memmap(path, dtype=dtype, mode="r", shape=shape)


_CODECS = {
    "encoded": BytesCodec,
    "decoded": NdarrayCodec,
    "augmented": NdarrayCodec,
}


def codec_for(form: str) -> Codec:
    """The codec serializing ``form`` entries on the disk tier."""
    try:
        return _CODECS[form]()
    except KeyError:
        raise ValueError(f"no codec registered for form {form!r}; "
                         f"known: {tuple(sorted(_CODECS))}") from None


def register_codec(form: str, factory: type) -> None:
    _CODECS[form] = factory


# ---------------------------------------------------------------------------
# Cross-process payload currency (the sharded data plane's zero-copy path).
#
# A shard process never pickles an ndarray payload over its control pipe:
# it dumps the entry with the form's codec into a shared exchange
# directory and sends this small :class:`PayloadRef` instead.  The peer
# maps the file (``np.memmap`` for ndarrays) and unlinks it — on Linux
# the mapping keeps the pages live, so the bytes move through the page
# cache, not the pipe.

@dataclass(frozen=True)
class PayloadRef:
    """A cache payload parked in a file: ``(form, path, nbytes, meta)``
    where ``meta`` is the form codec's load metadata."""

    form: str
    path: str
    nbytes: int
    meta: Any = None


def ship_payload(form: str, value: Any, path: str) -> PayloadRef:
    """Serialize ``value`` with ``form``'s codec into ``path`` and
    return the ref the receiving process redeems."""
    nbytes, meta = codec_for(form).dump(value, path)
    return PayloadRef(form, path, nbytes, meta)


def receive_payload(ref: PayloadRef, unlink: bool = True) -> Any:
    """Redeem a :class:`PayloadRef`: load (memmap) the value, then
    unlink the exchange file so nothing accumulates — safe because the
    mapping pins the pages until the array is dropped."""
    value = codec_for(ref.form).load(ref.path, ref.meta)
    if unlink:
        try:
            os.unlink(ref.path)
        except OSError:
            pass
    return value
