"""Cache tiers: the storage engines under a form's partition.

A :class:`~repro.cache.store.CachePartition` used to *be* an in-memory
``OrderedDict``; it is now a chain of tiers sharing one protocol:

* :class:`HbmTier` — device-resident store: payloads are ``jax.Array``
  (``device_put`` on insert, zero-copy serve into the training step);
* :class:`DramTier` — the original dict store, behavior-identical;
* :class:`DiskTier` — a directory of per-entry files (one file per
  cached sample, serialized by the form's
  :mod:`~repro.cache.codecs` codec, ndarrays read back via
  ``np.memmap`` zero-copy).

Tiers are dumb byte-accounted stores; *chain* behavior (demote on
eviction, promote on hit) lives in ``CachePartition``, and all locking
stays with :class:`~repro.cache.store.TieredCache` — tier methods are
only ever called under the cache lock.  The one exception is the
:class:`DiskTier` write-behind: ``put`` *stages* the payload in memory
under the lock, and the file write/fsync runs in
:meth:`DiskTier.flush_staged` with the lock **released** around the IO,
so a slow SSD never stalls concurrent lookups (the TieredCache flushes
before each public method returns, keeping the index↔files invariant at
op boundaries).

``put`` / ``set_capacity`` return the entries they evicted as
``(key, value, nbytes)`` triples so a chain can demote them into the
next tier; a terminal tier returns ``value=None`` (nothing consumes it).
"""
from __future__ import annotations

import heapq
import os
from collections import OrderedDict
from dataclasses import dataclass
from typing import (Any, Dict, List, Optional, Protocol, Tuple,
                    runtime_checkable)

from repro.cache.codecs import codec_for

#: sentinel distinguishing "absent" from a legitimately stored falsy /
#: ``None`` payload (an empty encoded sample must count as a hit)
MISS = object()

#: DiskTier index meta for an entry whose file write is still staged
#: (write-behind: the payload is in ``_staged``, not yet on disk)
_PENDING = object()

Evicted = List[Tuple[int, Any, int]]


@dataclass
class PartitionStats:
    hits: int = 0
    misses: int = 0
    inserts: int = 0
    evictions: int = 0
    bytes_used: int = 0

    @classmethod
    def merged(cls, parts: "List[PartitionStats]") -> "PartitionStats":
        """Aggregate stripe-local ledgers into one view (the striped
        TieredCache keeps byte accounting per stripe so the hot path
        never contends on a shared counter; readers sum on demand)."""
        out = cls()
        for p in parts:
            out.hits += p.hits
            out.misses += p.misses
            out.inserts += p.inserts
            out.evictions += p.evictions
            out.bytes_used += p.bytes_used
        return out


@runtime_checkable
class Tier(Protocol):
    """One byte-accounted key/value level of a partition chain."""

    capacity: int
    policy: str
    stats: PartitionStats

    def __contains__(self, key: int) -> bool: ...
    def __len__(self) -> int: ...
    def keys(self) -> List[int]: ...
    def get(self, key: int, default: Any = None) -> Any: ...
    def peek(self, key: int, default: Any = None) -> Any: ...
    def put(self, key: int, value: Any, nbytes: int) -> Evicted: ...
    def set_capacity(self, capacity_bytes: int) -> Evicted: ...
    def remove(self, key: int) -> bool: ...
    def admits(self, nbytes: int) -> bool: ...
    @property
    def free_bytes(self) -> int: ...


class DramTier:
    """In-memory dict store with byte accounting + LRU order (the
    original ``CachePartition`` engine, extracted verbatim)."""

    #: policies that make room inside put() by evicting; the others
    #: ("none"/"refcount") reject inserts that do not fit immediately
    MAKES_ROOM = ("lru", "cost")

    def __init__(self, capacity_bytes: int, evict_policy: str = "none"):
        assert evict_policy in ("none", "lru", "refcount", "cost")
        self.capacity = int(capacity_bytes)
        self.policy = evict_policy
        self._data: "OrderedDict[int, Any]" = OrderedDict()
        self._sizes: Dict[int, int] = {}
        self.stats = PartitionStats()
        # "cost" (GDSF, greedy-dual-size-frequency): every entry carries
        # priority L + recompute_cost/nbytes; eviction pops the minimum
        # and raises the inflation floor L to the victim's priority, so
        # long-untouched entries age out while expensive-to-recompute
        # ones persist.  The heap is lazily invalidated: _pri holds the
        # live priority, stale heap items are skipped on pop.
        self._pri: Dict[int, float] = {}
        self._heap: List[Tuple[float, int]] = []
        self._inflation = 0.0
        #: seconds to rebuild one entry of this form from storage (the
        #: telemetry-measured t_da/t_a chain), pushed by
        #: TieredCache.set_form_costs; 1.0 until telemetry warms up
        self.recompute_cost = 1.0

    # -- "cost" (GDSF) bookkeeping -------------------------------------
    def set_cost(self, seconds: float) -> None:
        """Update the recompute cost feeding future priorities (existing
        entries re-score on their next touch)."""
        self.recompute_cost = max(float(seconds), 1e-9)

    def _touch(self, key: int, nbytes: int) -> None:
        pri = self._inflation + self.recompute_cost / max(nbytes, 1)
        self._pri[key] = pri
        heapq.heappush(self._heap, (pri, key))

    def _evict_min_cost(self) -> Tuple[int, Any, int]:
        """Pop the minimum-priority live entry (skipping stale heap
        items) and raise the inflation floor to its priority."""
        while self._heap:
            pri, k = heapq.heappop(self._heap)
            if k in self._data and self._pri.get(k) == pri:
                self._pri.pop(k, None)
                self._inflation = pri
                v = self._data.pop(k)
                nb = self._sizes.pop(k)
                return k, v, nb
        # heap drained with live entries left (shouldn't happen; the
        # heap holds at least one item per live key) — FIFO fallback
        k, v = self._data.popitem(last=False)
        self._pri.pop(k, None)
        return k, v, self._sizes.pop(k)

    def _evict_victim(self) -> Tuple[int, Any, int]:
        """Remove and return one entry in policy order: min GDSF
        priority for "cost", LRU order for "lru" (move_to_end keeps the
        OrderedDict sorted by recency), insertion/FIFO otherwise."""
        if self.policy == "cost":
            return self._evict_min_cost()
        k, v = self._data.popitem(last=False)
        return k, v, self._sizes.pop(k)

    def __contains__(self, key: int) -> bool:
        return key in self._data

    def __len__(self) -> int:
        return len(self._data)

    def keys(self) -> List[int]:
        return list(self._data.keys())

    def get(self, key: int, default: Any = None) -> Any:
        v = self._data.get(key, MISS)
        if v is MISS:
            self.stats.misses += 1
            return default
        self.stats.hits += 1
        if self.policy == "lru":
            self._data.move_to_end(key)
        elif self.policy == "cost":
            self._touch(key, self._sizes[key])
        return v

    def peek(self, key: int, default: Any = None) -> Any:
        """Stats-neutral read: no hit/miss counting, no LRU promotion."""
        v = self._data.get(key, MISS)
        return default if v is MISS else v

    def admits(self, nbytes: int) -> bool:
        """Could ``put`` accept an entry of ``nbytes`` right now?  Only
        "lru"/"cost" make room inside put(); "none"/"refcount" reject
        when full, so the entry must fit immediately."""
        if self.capacity == 0 or nbytes > self.capacity:
            return False
        return self.policy in self.MAKES_ROOM or self.free_bytes >= nbytes

    def put(self, key: int, value: Any, nbytes: int) -> Evicted:
        """Insert; returns evicted entries (never evicts under 'none' —
        the insert is rejected instead, MINIO-style).  Re-inserting an
        existing key replaces it (the old entry is dropped first, so a
        rejected oversized replacement leaves the key absent, not
        half-accounted)."""
        evicted: Evicted = []
        if key in self._data:
            del self._data[key]
            self.stats.bytes_used -= self._sizes.pop(key)
            self._pri.pop(key, None)
        while self.stats.bytes_used + nbytes > self.capacity:
            if self.policy in self.MAKES_ROOM and self._data:
                k, v, nb = self._evict_victim()
                self.stats.bytes_used -= nb
                self.stats.evictions += 1
                evicted.append((k, v, nb))
            else:
                return evicted           # rejected (no-evict policy)
        self._data[key] = value
        self._sizes[key] = nbytes
        self.stats.bytes_used += nbytes
        self.stats.inserts += 1
        if self.policy == "cost":
            self._touch(key, nbytes)
        return evicted

    def set_capacity(self, capacity_bytes: int) -> Evicted:
        """Resize live; returns the entries evicted to fit (policy order:
        LRU order for "lru", min GDSF priority for "cost",
        insertion/FIFO order otherwise)."""
        self.capacity = int(capacity_bytes)
        evicted: Evicted = []
        while self.stats.bytes_used > self.capacity and self._data:
            k, v, nb = self._evict_victim()
            self.stats.bytes_used -= nb
            self.stats.evictions += 1
            evicted.append((k, v, nb))
        return evicted

    def remove(self, key: int) -> bool:
        if key in self._data:
            del self._data[key]
            self.stats.bytes_used -= self._sizes.pop(key)
            self._pri.pop(key, None)
            self.stats.evictions += 1
            return True
        return False

    def pop_entry(self, key: int):
        """Stats-neutral removal returning ``(value, nbytes)`` or None —
        the chain's demote/promote plumbing (a migration between tiers
        is not an eviction)."""
        if key not in self._data:
            return None
        v = self._data.pop(key)
        nb = self._sizes.pop(key)
        self._pri.pop(key, None)
        self.stats.bytes_used -= nb
        return v, nb

    @property
    def free_bytes(self) -> int:
        return self.capacity - self.stats.bytes_used


class DiskTier:
    """Spill tier: one file per entry under ``root/<form>/``.

    Entries are serialized by the form's codec (encoded bytes pass
    through; decoded/augmented ndarrays become raw contiguous buffers
    read back via ``np.memmap``).  Accounting mirrors :class:`DramTier`
    — the byte ledger tracks caller-declared entry sizes, and eviction
    is LRU by default (a spill area wants recency, not MINIO
    rejection).  Metadata (sizes, dtypes/shapes) stays in memory: the
    tier is process-local scratch, not a persistent store.
    """

    def __init__(self, capacity_bytes: int, root: str, form: str,
                 evict_policy: str = "lru"):
        assert evict_policy in ("none", "lru")
        self.capacity = int(capacity_bytes)
        self.policy = evict_policy
        self.form = form
        self.dir = os.path.join(root, form)
        os.makedirs(self.dir, exist_ok=True)
        self.codec = codec_for(form)
        # key -> (nbytes, codec meta); OrderedDict gives LRU order
        self._index: "OrderedDict[int, Tuple[int, Any]]" = OrderedDict()
        # write-behind staging: key -> payload awaiting its file write
        # (index meta is _PENDING meanwhile; get/peek serve from here)
        self._staged: Dict[int, Any] = {}
        # keys whose file write a flush_staged caller has claimed and is
        # running outside the lock — other flushers must not pick them
        # up, or two threads would dump to the same path concurrently
        self._inflight: set = set()
        self.stats = PartitionStats()
        self.io_errors = 0

    # ------------------------------------------------------------------
    def _path(self, key: int) -> str:
        return os.path.join(self.dir, f"{key}.bin")

    def __contains__(self, key: int) -> bool:
        return key in self._index

    def __len__(self) -> int:
        return len(self._index)

    def keys(self) -> List[int]:
        return list(self._index.keys())

    def admits(self, nbytes: int) -> bool:
        if self.capacity == 0 or nbytes > self.capacity:
            return False
        return self.policy == "lru" or self.free_bytes >= nbytes

    def get(self, key: int, default: Any = None) -> Any:
        entry = self._index.get(key, MISS)
        if entry is MISS:
            self.stats.misses += 1
            return default
        staged = self._staged.get(key, MISS)
        if staged is not MISS:
            # write still pending: serve the in-memory payload directly
            self.stats.hits += 1
            if self.policy == "lru":
                self._index.move_to_end(key)
            return staged
        nbytes, meta = entry
        try:
            value = self.codec.load(self._path(key), meta)
        except (OSError, ValueError):
            # the file vanished or is shorter than dtype*shape claims
            # (external cleanup, or truncated mid-rewrite — np.memmap
            # raises ValueError for short files): drop the index entry
            # rather than serving a phantom hit.  Counted in io_errors
            # only — the chain's lookup counts the resulting miss at
            # lookup granularity, so counting here would double
            self.io_errors += 1
            self._drop(key)
            return default
        self.stats.hits += 1
        if self.policy == "lru":
            self._index.move_to_end(key)
        return value

    def peek(self, key: int, default: Any = None) -> Any:
        entry = self._index.get(key, MISS)
        if entry is MISS:
            return default
        staged = self._staged.get(key, MISS)
        if staged is not MISS:
            return staged
        try:
            return self.codec.load(self._path(key), entry[1])
        except (OSError, ValueError):
            self.io_errors += 1
            self._drop(key)
            return default

    def put(self, key: int, value: Any, nbytes: int) -> Evicted:
        """Insert (or demotion from the DRAM tier).  Returns the entries
        evicted to make room with ``value=None`` — a disk eviction is
        terminal, nothing downstream consumes the payload.

        Write-behind: the payload is only *staged* here (the caller
        holds the cache lock); the file write happens in
        :meth:`flush_staged` with the lock released around the IO."""
        evicted: Evicted = []
        if key in self._index:
            self._drop(key)
        if not self.admits(nbytes):
            return evicted
        while self.stats.bytes_used + nbytes > self.capacity:
            if self.policy == "lru" and self._index:
                k = next(iter(self._index))
                nb = self._index[k][0]
                self._drop(k)
                self.stats.evictions += 1
                evicted.append((k, None, nb))
            else:
                return evicted
        self._index[key] = (nbytes, _PENDING)
        self._staged[key] = value
        self.stats.bytes_used += nbytes
        self.stats.inserts += 1
        return evicted

    def flush_staged(self, lock) -> None:
        """Drain the write-behind stage: claim one staged payload under
        ``lock``, run the codec dump (write + fsync) with the lock
        *released*, then commit the codec meta back under the lock.

        Claims are marked in ``_inflight`` so concurrent flushers never
        pick the same key — two threads dumping to one path outside the
        lock would race truncate-and-rewrite against a reader.  A
        flusher finding only in-flight keys returns; their claimants
        commit them.  Concurrent drops/replacements while a write is in
        flight are reconciled at commit time: a dropped key's orphan
        file is unlinked, a replaced key stays staged (its newer payload
        is picked up by a later iteration).  TieredCache calls this
        after releasing its lock from every mutating public method, so
        at op boundaries the stage is empty and index == files on
        disk."""
        if not self._staged:
            # racy-but-benign fast path: callers flush after their own
            # mutation, so missing a concurrent stage just defers it to
            # that op's flush
            return
        while True:
            with lock:
                key = next((k for k in self._staged
                            if k not in self._inflight), MISS)
                if key is MISS:
                    # nothing unclaimed (empty, or every remaining key's
                    # write is owned by another flusher)
                    return
                value = self._staged[key]
                self._inflight.add(key)
            path = self._path(key)
            err = False
            try:
                _written, meta = self.codec.dump(value, path)
            except OSError:
                err = True
            with lock:
                self._inflight.discard(key)
                if self._staged.get(key, MISS) is not value:
                    # dropped or replaced mid-write; if nothing current
                    # claims the key, the file we just wrote is an orphan
                    if key not in self._index:
                        try:
                            os.unlink(path)
                        except OSError:
                            pass
                    continue
                del self._staged[key]
                entry = self._index.get(key)
                if entry is None:
                    continue
                if err:
                    # a failed spill write is a rejected insert, not a
                    # crash on the serving path; leave no partial file
                    self.io_errors += 1
                    nbytes, _m = self._index.pop(key)
                    self.stats.bytes_used -= nbytes
                    try:
                        os.unlink(path)
                    except OSError:
                        pass
                else:
                    self._index[key] = (entry[0], meta)

    def set_capacity(self, capacity_bytes: int) -> Evicted:
        self.capacity = int(capacity_bytes)
        evicted: Evicted = []
        while self.stats.bytes_used > self.capacity and self._index:
            k = next(iter(self._index))
            nb = self._index[k][0]
            self._drop(k)
            self.stats.evictions += 1
            evicted.append((k, None, nb))
        return evicted

    def remove(self, key: int) -> bool:
        if key in self._index:
            self._drop(key)
            self.stats.evictions += 1
            return True
        return False

    def size_of(self, key: int) -> Optional[int]:
        entry = self._index.get(key)
        return entry[0] if entry is not None else None

    def discard(self, key: int) -> bool:
        """Stats-neutral drop (promotions and replacements are tier
        migrations, not evictions)."""
        if key in self._index:
            self._drop(key)
            return True
        return False

    def _drop(self, key: int) -> None:
        nbytes, _meta = self._index.pop(key)
        self._staged.pop(key, None)
        self.stats.bytes_used -= nbytes
        try:
            os.unlink(self._path(key))
        except OSError:
            pass

    @property
    def free_bytes(self) -> int:
        return self.capacity - self.stats.bytes_used

    # ------------------------------------------------------------------
    def clear(self) -> None:
        """Drop every entry and its file, then the form directory (the
        no-leaked-files teardown contract: ``server.close()`` leaves
        the spill dir empty)."""
        for key in list(self._index):
            self._drop(key)
        try:
            os.rmdir(self.dir)
        except OSError:
            pass


class HbmTier(DramTier):
    """Device-resident tier at the head of a partition chain.

    Payloads are held as ``jax.Array`` on the default device —
    ``jax.device_put`` on insert, so a hit serves the accelerator-side
    buffer zero-copy into the training step (on the CPU backend the
    semantics and accounting are identical; only the memory space
    differs).  Accounting, eviction policies and the chain protocol are
    inherited from :class:`DramTier`; byte sizes stay caller-declared
    (host-side nbytes — the MDP's currency).

    Only array payloads are admitted (:meth:`wants_value`): raw encoded
    ``bytes`` gain nothing from device residency and would force a
    host copy on every decode, so the chain routes them to DRAM.
    """

    def __init__(self, capacity_bytes: int, evict_policy: str = "none"):
        super().__init__(capacity_bytes, evict_policy)
        import jax  # baked into the toolchain; fail loud if absent
        self._jax = jax

    @staticmethod
    def wants_value(value: Any) -> bool:
        """Device-residency eligibility: ndarray-like payloads only."""
        return hasattr(value, "__array__") or hasattr(value, "dtype")

    def to_device(self, value: Any):
        """Host payload -> device array (no-op for resident arrays)."""
        return self._jax.device_put(value)

    def put(self, key: int, value: Any, nbytes: int) -> Evicted:
        return super().put(key, self.to_device(value), nbytes)
