"""Cross-job production coalescing (single-flight, CoorDL-style).

K concurrent jobs with overlapping working sets run the same
fetch+decode+augment chain up to K times for the same ``(sample_id,
form)`` — coordinated prep that dedups that work is the largest
multi-job win in the data-stall literature.  A :class:`ProductionTable`
tracks in-flight productions: the first misser becomes the *leader*
(produces and admits as usual), concurrent missers *join* the flight
and receive the leader's result zero-copy instead of re-running the
chain.

VirtualClock safety: a joiner under a deterministic clock must not
block on a :class:`threading.Event` — the leader may itself be parked
in the clock's turn discipline (e.g. a token-bucket storage stall), and
a wall-blocked waiter would freeze the whole dispatch loop.  Joiners
with a bound ticket instead poll the flight through ``Clock.stall``
micro-sleeps, which parks them as regular participants and charges the
wait as (deterministic) virtual time.  Threads that cannot wait safely
— deterministic clock but no bound ticket — decline to join and
produce the sample themselves, trading a duplicate production for
liveness.

The table never stores payloads beyond the hand-off: a flight is
removed the moment its leader finishes (or aborts), so the memory cost
is O(in-flight keys), not O(cache).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["ProductionTable", "Flight"]


class Flight:
    """One in-flight production of a ``(sample_id, form)`` key."""

    __slots__ = ("key", "event", "value", "error", "done", "waiters")

    def __init__(self, key: Tuple[int, str]):
        self.key = key
        self.event = threading.Event()
        self.value = None
        self.error: Optional[BaseException] = None
        self.done = False
        self.waiters = 0


class ProductionTable:
    """Single-flight dedup of ``(sample_id, form)`` productions.

    ``enabled=False`` keeps the table in *observe* mode: every caller
    produces (the baseline behavior) but concurrent productions of the
    same key are still counted in :attr:`duplicates` — that counter is
    how the concurrency benchmark proves coalescing drives duplicate
    productions to ~0.
    """

    #: virtual seconds charged per join poll under a deterministic clock
    POLL_TICK = 1e-4
    #: poll budget: a joiner gives up (and produces itself) after this
    #: many ticks, so a dead leader can never strand it
    MAX_POLLS = 50_000

    def __init__(self, enabled: bool = True, timeout_s: float = 5.0):
        self._lock = threading.Lock()
        self._flights: Dict[Tuple[int, str], Flight] = {}
        self.enabled = bool(enabled)
        self.timeout_s = float(timeout_s)
        # counters (read unlocked by stats paths; written under _lock)
        self.led = 0            # unique productions that went through begin
        self.coalesced = 0      # productions avoided by joining a flight
        self.coalesce_wait_s = 0.0
        self.duplicates = 0     # productions begun while the key was
        #                         already in flight (observe mode, or
        #                         joiners that could not wait safely)

    # ------------------------------------------------------------------
    def begin(self, sid: int, form: str) -> Tuple[bool, Optional[Flight]]:
        """Claim a production.  Returns ``(leader, flight)``:

        * ``(True, flight)`` — the caller is the leader; it must call
          :meth:`finish` (or :meth:`abort`) with this flight.
        * ``(True, None)`` — coalescing is disabled and another
          production of the key is already in flight; produce anyway
          (counted as a duplicate), with nothing to finish.
        * ``(False, flight)`` — join the flight via :meth:`join`.
        """
        key = (int(sid), form)
        with self._lock:
            fl = self._flights.get(key)
            if fl is None:
                fl = Flight(key)
                self._flights[key] = fl
                self.led += 1
                return True, fl
            if not self.enabled:
                self.duplicates += 1
                return True, None
            fl.waiters += 1
            return False, fl

    def finish(self, flight: Optional[Flight], value) -> None:
        """Leader hand-off: publish ``value`` to every joiner (zero-copy
        — they receive this exact object) and retire the flight."""
        if flight is None:
            return
        with self._lock:
            # identity check: a timed-out flight may have been evicted
            # and superseded — never pop the successor's flight
            if self._flights.get(flight.key) is flight:
                del self._flights[flight.key]
        flight.value = value
        flight.done = True
        flight.event.set()

    def abort(self, flight: Optional[Flight],
              error: Optional[BaseException] = None) -> None:
        """Leader failure path: wake joiners empty-handed (they retry
        :meth:`begin`, and the first becomes the new leader)."""
        if flight is None:
            return
        with self._lock:
            if self._flights.get(flight.key) is flight:
                del self._flights[flight.key]
        # never leave error None on an abort: join() reads error-is-None
        # as success, and a None payload must not masquerade as a value
        flight.error = error if error is not None else \
            RuntimeError("production aborted")
        flight.done = True
        flight.event.set()

    # ------------------------------------------------------------------
    def join(self, flight: Flight, clock=None
             ) -> Tuple[bool, Optional[object]]:
        """Wait for a flight's result.  Returns ``(ok, value)``; ``ok``
        False means the flight aborted or the wait was abandoned — the
        caller should fall back to producing the sample itself.

        ``clock`` is the caller's duck-typed Clock (or None for wall
        time).  Deterministic clocks are polled via :meth:`Clock.stall`
        (see module doc); everything else blocks on the flight event
        with a wall timeout.
        """
        wall = clock is None or not getattr(clock, "deterministic", False)
        now = time.monotonic if clock is None else clock.now
        t0 = now()
        if not flight.done:
            if wall:
                flight.event.wait(self.timeout_s)
            else:
                if clock.bound_ticket() is None:
                    # cannot park as a clock participant: waiting would
                    # stall the dispatch loop.  Duplicate, but live.
                    with self._lock:
                        self.duplicates += 1
                    return False, None
                polls = 0
                while not flight.done and polls < self.MAX_POLLS:
                    clock.stall(self.POLL_TICK)
                    polls += 1
            if not flight.done:
                # leader presumed dead (dropped mid-shutdown, wedged):
                # evict the orphan so later missers lead fresh flights
                # instead of re-paying this timeout forever
                with self._lock:
                    if self._flights.get(flight.key) is flight:
                        del self._flights[flight.key]
                    self.duplicates += 1
        if flight.done and flight.error is None:
            with self._lock:
                self.coalesced += 1
                self.coalesce_wait_s += max(now() - t0, 0.0)
            return True, flight.value
        return False, None

    # ------------------------------------------------------------------
    def inflight_ids(self) -> List[int]:
        with self._lock:
            return [k[0] for k in self._flights]

    def inflight_mask(self, n: int) -> Optional[np.ndarray]:
        """bool[n] mask of sample ids with an in-flight production, or
        None when the table is idle (the common case — callers gate the
        O(N) mask work and keep the ODS fast path byte-identical)."""
        with self._lock:
            if not self._flights:
                return None
            ids = [k[0] for k in self._flights if 0 <= k[0] < n]
        if not ids:
            return None
        mask = np.zeros(n, bool)
        mask[ids] = True
        return mask

    def __len__(self) -> int:
        with self._lock:
            return len(self._flights)

    def stats(self) -> Dict[str, float]:
        with self._lock:
            return {
                "enabled": self.enabled,
                "led": self.led,
                "coalesced": self.coalesced,
                "coalesce_wait_s": self.coalesce_wait_s,
                "duplicates": self.duplicates,
                "in_flight": len(self._flights),
            }
